//! GPU placement as a first-class planner subsystem (§5.1/§5.3).
//!
//! Grown from the offline `sim::cluster::pack` oracle: the same
//! first-fit-decreasing packing under the per-GPU share cap (≤ 100%)
//! and memory capacity, but run *inside* `Scheduler::plan`, producing
//! per-instance GPU assignments that are stamped into the
//! [`ExecutionPlan`] (`StagePlan::gpus`) and consumed downstream by
//! the serving executor (GPU-affinity shard→worker mapping), the
//! deployment manifest and the placement benches.
//!
//! When placement fails (an instance that cannot fit any single GPU) or
//! fragments badly (far more GPUs than the share lower bound), the
//! scheduler re-enters re-partitioning with tightened per-instance
//! ceilings ([`crate::profiler::AllocConstraints::max_share`] /
//! `max_instance_mem_mb`) instead of emitting an unpackable plan — see
//! `Scheduler::plan`.  `sim::cluster::pack` stays untouched as the
//! post-hoc reference oracle: property tests assert the integrated
//! planner never uses more GPUs than FFD-packing the same demand after
//! the fact, and never violates a cap.

use std::collections::BTreeMap;

use super::plan::ExecutionPlan;
use crate::profiler::{Alloc, CostModel};

/// Knobs for the planner-integrated placement pass.
#[derive(Debug, Clone)]
pub struct PlacementOptions {
    /// Run placement inside `Scheduler::plan` (on by default; off gives
    /// the pre-placement planner for oracle comparisons).
    pub enabled: bool,
    /// Hard cluster size; `None` = grow as needed.
    pub max_gpus: Option<usize>,
    /// Feedback trigger: the tolerated fraction of placed GPUs in
    /// excess of the GPU-count lower bound (the larger of the share
    /// bound `⌈total_share/max_share⌉` and the memory bound
    /// [`gpus_mem_lower_bound`]) before the scheduler re-enters
    /// re-partitioning with tightened ceilings.
    pub frag_threshold: f64,
    /// Maximum tightening rounds the feedback loop may evaluate.
    pub max_rounds: usize,
    /// How much total-share inflation a GPU-saving tightened plan may
    /// cost: accepted only when `cand_share ≤ round0_share × (1 +
    /// share_slack)`.  The default 0.0 keeps the planner share-optimal
    /// (tightening is only accepted when the instance-granularity slack
    /// makes it free), so share-metric comparisons against baselines
    /// are unaffected; the capped-resource regime (Fig 17) can trade
    /// share for GPUs by raising it.  An unplaceable round-0 plan is
    /// always rescued regardless of slack.
    pub share_slack: f64,
}

impl Default for PlacementOptions {
    fn default() -> Self {
        Self {
            enabled: true,
            max_gpus: None,
            frag_threshold: 0.25,
            max_rounds: 2,
            share_slack: 0.0,
        }
    }
}

/// Stream-merge entry point for the sharded planner: concatenate
/// per-shard instance streams — already in ascending shard (model)
/// order — into the single plan the global placement pass packs.
/// Placement deliberately stays global: FFD bin-packing is a
/// cross-model optimisation (instances of different models share
/// GPUs), so packing shards independently would change GPU counts;
/// only the stages *before* placement are per-model independent.
/// Pure concatenation ([`ExecutionPlan::merge_with`] preserves set
/// order), so the merged stream is byte-identical to what the
/// sequential pipeline would have emitted.
pub fn merge_shard_streams(
    shards: impl IntoIterator<Item = ExecutionPlan>,
) -> ExecutionPlan {
    let mut plan = ExecutionPlan::default();
    for p in shards {
        plan.merge_with(p);
    }
    plan
}

/// Unused share fraction of a packing: `1 − used / (gpus · max_share)`
/// (0 for an empty packing).  The single definition shared by the
/// planner-integrated [`Placement`] and the offline `sim::cluster`
/// oracle so the two sides of the bench always compare the same metric.
pub fn share_fragmentation(
    used_share: u64,
    gpus: usize,
    max_share: u32,
) -> f64 {
    if gpus == 0 || max_share == 0 {
        return 0.0;
    }
    1.0 - used_share as f64 / (gpus as u64 * max_share as u64) as f64
}

/// Aggregate load of one GPU.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GpuUsage {
    pub share: u32,
    pub mem_mb: f64,
}

/// A full placement of a plan: per-GPU usage plus per-stage,
/// per-instance GPU ids in [`ExecutionPlan::stages`] order.
#[derive(Debug, Clone, Default)]
pub struct Placement {
    pub usage: Vec<GpuUsage>,
    /// `by_stage[stage][instance] = gpu`, stages in plan order.
    pub by_stage: Vec<Vec<u32>>,
}

impl Placement {
    pub fn gpus(&self) -> usize {
        self.usage.len()
    }

    /// Unused share fraction across the placed GPUs (0 for an empty
    /// placement): `1 − used / (gpus · max_share)`.
    pub fn fragmentation(&self, max_share: u32) -> f64 {
        let used: u64 = self.usage.iter().map(|u| u.share as u64).sum();
        share_fragmentation(used, self.usage.len(), max_share)
    }

    /// Fraction of placed GPUs in excess of a lower bound — the
    /// feedback-loop trigger metric (0 when packing is bound-tight).
    pub fn excess_over(&self, lower_bound: usize) -> f64 {
        if self.usage.is_empty() {
            return 0.0;
        }
        self.usage.len().saturating_sub(lower_bound) as f64
            / self.usage.len() as f64
    }
}

/// Memory-only lower bound on a plan's GPU count: `⌈Σ instance memory
/// / gpu_mem_mb⌉`.  Complements `ExecutionPlan::gpus_share_lower_bound`
/// in the feedback trigger: tightening share ceilings can never beat a
/// memory-bound packing, so excess is measured against the larger of
/// the two bounds — a memory-bound fleet does not fire futile
/// tightening rounds on every trigger.
pub fn gpus_mem_lower_bound(cm: &CostModel, plan: &ExecutionPlan) -> usize {
    let g = &cm.config().gpu;
    if g.gpu_mem_mb <= 0.0 {
        return 0;
    }
    let total: f64 = plan
        .stages()
        .map(|s| {
            s.alloc.instances as f64 * cm.instance_mem_mb(s.frag, s.alloc.batch)
        })
        .sum();
    (total / g.gpu_mem_mb).ceil() as usize
}

/// Placement failure: some instance exceeds a single GPU's capacity.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Unplaceable {
    /// Index into [`ExecutionPlan::stages`] order.
    pub stage: usize,
    pub share: u32,
    pub mem_mb: f64,
    /// `true` when the cluster cap (`max_gpus`) is what ran out rather
    /// than a single GPU's capacity.
    pub cluster_full: bool,
}

/// Per-GPU placement constraints beyond the base caps: hard avoidance
/// (dead hardware — never placed on), *soft* avoidance (suspect
/// hardware — last-resort bins: the packing first tries to succeed
/// without them and only spills onto them when the cluster cap leaves
/// no alternative), and per-GPU residual capacity losses (degraded
/// hardware that keeps serving at reduced share/memory).
///
/// An empty constraint set makes every constrained entry point
/// byte-identical to its unconstrained counterpart — soft avoidance is
/// *advisory only*, property-tested in `tests/proptests.rs`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlacementConstraints {
    /// Never place here (failed hardware).
    pub hard_avoid: Vec<u32>,
    /// Prefer not to place here (suspect hardware).
    pub soft_avoid: Vec<u32>,
    /// Compute share lost per GPU (subtracted from `max_share`).
    pub share_loss: BTreeMap<u32, u32>,
    /// Memory lost per GPU in MB (subtracted from `gpu_mem_mb`).
    pub mem_loss_mb: BTreeMap<u32, f64>,
}

impl PlacementConstraints {
    /// The emergency-replan shape: dead GPUs only.
    pub fn hard_only(avoid: &[u32]) -> Self {
        Self { hard_avoid: avoid.to_vec(), ..Default::default() }
    }

    pub fn is_empty(&self) -> bool {
        self.hard_avoid.is_empty()
            && self.soft_avoid.is_empty()
            && self.share_loss.is_empty()
            && self.mem_loss_mb.is_empty()
    }

    pub fn is_hard(&self, gpu: u32) -> bool {
        self.hard_avoid.contains(&gpu)
    }

    pub fn is_soft(&self, gpu: u32) -> bool {
        self.soft_avoid.contains(&gpu)
    }

    /// Hard or soft avoided (the pin filter for delta replacement).
    pub fn is_avoided(&self, gpu: u32) -> bool {
        self.is_hard(gpu) || self.is_soft(gpu)
    }

    /// Residual share capacity of `gpu` given the base cap.
    pub fn share_cap(&self, gpu: u32, base: u32) -> u32 {
        base.saturating_sub(self.share_loss.get(&gpu).copied().unwrap_or(0))
    }

    /// Residual memory capacity of `gpu` given the base cap.
    pub fn mem_cap(&self, gpu: u32, base: f64) -> f64 {
        (base - self.mem_loss_mb.get(&gpu).copied().unwrap_or(0.0)).max(0.0)
    }
}

/// First-fit-decreasing placement of every instance of `plan` under the
/// configured per-GPU share cap and memory capacity.  Deterministic:
/// items are ordered by (share desc, memory desc) with stable
/// tie-breaking on plan order — the same discipline as the
/// `sim::cluster::pack` oracle, so an untightened plan places onto
/// exactly the oracle's GPU count.
pub fn place(
    cm: &CostModel,
    plan: &ExecutionPlan,
    max_gpus: Option<usize>,
) -> Result<Placement, Unplaceable> {
    place_avoiding(cm, plan, max_gpus, &[])
}

/// [`place`] with a set of GPU ids nothing may be placed on (failed
/// hardware during an emergency replan).  Avoided ids below the
/// allocated range appear in the usage vector as empty entries so every
/// other id keeps its meaning; avoided ids are never handed out and do
/// not count against `max_gpus`.
pub fn place_avoiding(
    cm: &CostModel,
    plan: &ExecutionPlan,
    max_gpus: Option<usize>,
    avoid: &[u32],
) -> Result<Placement, Unplaceable> {
    place_items(
        cm,
        plan,
        max_gpus,
        &PlacementConstraints::hard_only(avoid),
        false,
    )
}

/// [`place`] under full [`PlacementConstraints`]: hard-avoided GPUs are
/// excluded, degraded GPUs offer only their residual capacity, and
/// soft-avoided (suspect) GPUs are last-resort bins — a *strict* pass
/// first treats them as excluded, and only when that pass dies on the
/// cluster cap does a second pass let items spill onto suspects.  With
/// no cap the strict pass always succeeds (fresh GPUs absorb the
/// displaced load), so suspects end up fully vacated.  An empty
/// constraint set is byte-identical to [`place`].
pub fn place_constrained(
    cm: &CostModel,
    plan: &ExecutionPlan,
    max_gpus: Option<usize>,
    cons: &PlacementConstraints,
) -> Result<Placement, Unplaceable> {
    if cons.soft_avoid.is_empty() {
        return place_items(cm, plan, max_gpus, cons, false);
    }
    let mut strict = cons.clone();
    strict.hard_avoid.extend(strict.soft_avoid.iter().copied());
    strict.soft_avoid.clear();
    match place_items(cm, plan, max_gpus, &strict, false) {
        Ok(p) => Ok(p),
        // only a cap failure justifies touching suspects; a too-big
        // single instance fails either way
        Err(e) if e.cluster_full => place_items(cm, plan, max_gpus, cons, true),
        Err(e) => Err(e),
    }
}

/// The FFD core shared by every placement entry point.  `soft_last`
/// arms the two-tier bin ordering: the first-fit pass skips soft
/// bins, and only when the cluster cap blocks opening a fresh bin does
/// a second pass consider them (suspect GPUs are live hardware inside
/// the provisioned cluster, so the cap counts healthy bins).  With
/// `soft_last == false` and no capacity losses this is exactly the
/// historical `place_avoiding` body.
fn place_items(
    cm: &CostModel,
    plan: &ExecutionPlan,
    max_gpus: Option<usize>,
    cons: &PlacementConstraints,
    soft_last: bool,
) -> Result<Placement, Unplaceable> {
    let g = &cm.config().gpu;
    // expand stages into placeable items
    let mut items: Vec<(usize, usize, u32, f64)> = Vec::new();
    let mut by_stage: Vec<Vec<u32>> = Vec::new();
    for (si, s) in plan.stages().enumerate() {
        let mem = cm.instance_mem_mb(s.frag, s.alloc.batch);
        if s.alloc.share > g.max_share || mem > g.gpu_mem_mb {
            return Err(Unplaceable {
                stage: si,
                share: s.alloc.share,
                mem_mb: mem,
                cluster_full: false,
            });
        }
        for inst in 0..s.alloc.instances as usize {
            items.push((si, inst, s.alloc.share, mem));
        }
        by_stage.push(vec![0; s.alloc.instances as usize]);
    }
    items.sort_by(|a, b| b.2.cmp(&a.2).then(b.3.total_cmp(&a.3)));

    let hard = |gpu: usize| cons.is_hard(gpu as u32);
    let soft = |gpu: usize| soft_last && cons.is_soft(gpu as u32);
    let share_cap = |gpu: usize| cons.share_cap(gpu as u32, g.max_share);
    let mem_cap = |gpu: usize| cons.mem_cap(gpu as u32, g.gpu_mem_mb);
    let mut usage: Vec<GpuUsage> = Vec::new();
    for (si, inst, share, mem) in items {
        let fits = |i: usize, u: &GpuUsage| {
            u.share + share <= share_cap(i) && u.mem_mb + mem <= mem_cap(i)
        };
        // first fit over healthy bins
        let mut slot = usage
            .iter()
            .enumerate()
            .position(|(i, u)| !hard(i) && !soft(i) && fits(i, u));
        if slot.is_none() {
            // idle soft placeholders (skipped below) do not count as
            // occupied cluster capacity
            let used = usage
                .iter()
                .enumerate()
                .filter(|(i, u)| {
                    !hard(*i)
                        && (!soft(*i) || u.share > 0 || u.mem_mb > 0.0)
                })
                .count();
            if max_gpus.is_some_and(|cap| used >= cap) {
                if soft_last {
                    // last resort: spill onto a suspect bin with room
                    slot = usage
                        .iter()
                        .enumerate()
                        .position(|(i, u)| soft(i) && !hard(i) && fits(i, u));
                }
                if slot.is_none() {
                    return Err(Unplaceable {
                        stage: si,
                        share,
                        mem_mb: mem,
                        cluster_full: true,
                    });
                }
            } else {
                // open a fresh bin, skipping over avoided / suspect /
                // too-degraded ids so they are never handed out here
                // (the loss maps are finite, so this terminates)
                loop {
                    let id = usage.len();
                    if hard(id)
                        || soft(id)
                        || share > share_cap(id)
                        || mem > mem_cap(id)
                    {
                        usage.push(GpuUsage::default());
                        continue;
                    }
                    usage.push(GpuUsage::default());
                    slot = Some(id);
                    break;
                }
            }
        }
        let gpu = slot.expect("slot resolved above");
        usage[gpu].share += share;
        usage[gpu].mem_mb += mem;
        by_stage[si][inst] = gpu as u32;
    }
    Ok(Placement { usage, by_stage })
}

/// Stamp a placement's GPU assignments into the plan's stages (the
/// planner does this once on the winning placement).
pub fn stamp(plan: &mut ExecutionPlan, placement: &Placement) {
    for (stage, gpus) in plan.stages_mut().zip(&placement.by_stage) {
        stage.gpus = gpus.clone();
    }
}

/// Verify a stamped plan against the caps: every stage fully placed and
/// no GPU above `max_share` / `gpu_mem_mb`.  Returns the per-GPU usage
/// reconstructed from the stamps (test/bench helper).
pub fn stamped_usage(
    cm: &CostModel,
    plan: &ExecutionPlan,
) -> Option<Vec<GpuUsage>> {
    let n = plan.placed_gpus()?;
    let mut usage = vec![GpuUsage::default(); n];
    for s in plan.stages() {
        let mem = cm.instance_mem_mb(s.frag, s.alloc.batch);
        for &gpu in &s.gpus {
            let u = &mut usage[gpu as usize];
            u.share += s.alloc.share;
            u.mem_mb += mem;
        }
    }
    Some(usage)
}

// ---------------------------------------------------------------------------
// Delta re-placement (migration-minimizing, live reconfiguration)
// ---------------------------------------------------------------------------

/// Result of a migration-minimizing delta placement
/// ([`place_delta`]).
#[derive(Debug, Clone)]
pub struct DeltaPlacement {
    /// The chosen placement of the new plan (delta-packed, or the full
    /// repack on the fallback path).  GPU ids are stable: pinned
    /// instances keep their previous id, so the usage vector may hold
    /// empty (vacated) GPUs.
    pub placement: Placement,
    /// Instances that stay exactly where they were.
    pub pinned: usize,
    /// Instances that must (re)start on a GPU: instances of new or
    /// changed stages, plus — on the fallback path — unchanged
    /// instances the repack moved anyway.
    pub migrated: usize,
    /// GPUs actually hosting at least one instance (≤
    /// `placement.gpus()` because vacated ids stay in the vector).
    pub gpus_used: usize,
    /// Migration count of the full-repack oracle on the same plan pair
    /// (`migrated ≤ repack_migrated` always holds).
    pub repack_migrated: usize,
    /// GPU count of the full-repack oracle.
    pub repack_gpus: usize,
    /// Delta packing would have needed more GPUs than the repack, so
    /// the repack was used instead — this is what guarantees the delta
    /// path never exceeds the oracle's GPU count.
    pub fell_back: bool,
}

/// Perturbation-stable identity of every stage in `plan.stages()`
/// order: stage kind (alignment/shared) + model + the sorted client-id
/// set the stage serves.  Client sets are disjoint across sets and
/// members, so identities are unique within a plan; across plans they
/// find "the same" logical instance group again after budgets, rates
/// or allocations moved (the same idea as
/// [`crate::coordinator::reuse::warm_signature`], applied per stage).
pub fn stage_identities(plan: &ExecutionPlan) -> Vec<u64> {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let ident = |kind: u8, model: usize, clients: &mut Vec<u32>| {
        clients.sort_unstable();
        let mut h = DefaultHasher::new();
        kind.hash(&mut h);
        model.hash(&mut h);
        clients.hash(&mut h);
        h.finish()
    };
    let mut out = Vec::new();
    for set in &plan.sets {
        // stages() order: members' alignment stages, then the shared
        for m in &set.members {
            if m.align.is_some() {
                let mut c: Vec<u32> =
                    m.spec.clients.iter().map(|c| c.0).collect();
                out.push(ident(0, set.model, &mut c));
            }
        }
        let mut c: Vec<u32> = set
            .members
            .iter()
            .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
            .collect();
        out.push(ident(1, set.model, &mut c));
    }
    out
}

/// Multiset overlap of two GPU-assignment lists: how many instances of
/// a stage can be considered "not moved" between two placements
/// (instances of one stage are fungible, so the fair count matches
/// assignments as multisets, not positionally).
fn gpu_overlap(a: &[u32], b: &[u32]) -> usize {
    let mut counts: std::collections::HashMap<u32, usize> =
        std::collections::HashMap::new();
    for &g in a {
        *counts.entry(g).or_insert(0) += 1;
    }
    let mut k = 0;
    for &g in b {
        if let Some(c) = counts.get_mut(&g) {
            if *c > 0 {
                *c -= 1;
                k += 1;
            }
        }
    }
    k
}

/// Migration-minimizing placement of `new` against the previously
/// deployed (stamped) `old` plan: instances of stages unchanged between
/// the plans (same identity, same fragment, same allocation) are
/// *pinned* to their current GPU; only the diff — instances of new or
/// changed stages — is FFD-packed into the vacated and residual
/// capacity.  The full repack ([`place`]) is always computed as the
/// oracle: if the delta packing would occupy more GPUs, the repack is
/// used instead (`fell_back`), so the result never exceeds the
/// oracle's GPU count while migrating no more instances than it
/// (`migrated ≤ repack_migrated`, property-tested).
///
/// `avoid` lists failed GPU ids (emergency replans): stages currently
/// stamped onto an avoided GPU are not pinned — their instances
/// restart elsewhere — and neither the delta pack nor the repack
/// oracle ever places onto an avoided id.
pub fn place_delta(
    cm: &CostModel,
    old: &ExecutionPlan,
    new: &ExecutionPlan,
    max_gpus: Option<usize>,
    avoid: &[u32],
) -> Result<DeltaPlacement, Unplaceable> {
    place_delta_constrained(
        cm,
        old,
        new,
        max_gpus,
        &PlacementConstraints::hard_only(avoid),
    )
}

/// [`place_delta`] under full [`PlacementConstraints`]: stages stamped
/// onto hard- *or* soft-avoided GPUs are unpinned (their instances
/// proactively migrate off dead and suspect hardware alike), pinned
/// stages must fit their GPUs' residual capacity (a degraded GPU sheds
/// whatever no longer fits), and the diff packs under the same
/// soft-last bin ordering as [`place_constrained`] — whose result is
/// also the repack oracle, so `migrated ≤ repack_migrated` and
/// `gpus_used ≤ repack_gpus` keep holding with constraints active.
/// Empty constraints are byte-identical to [`place_delta`] with an
/// empty avoid set.
pub fn place_delta_constrained(
    cm: &CostModel,
    old: &ExecutionPlan,
    new: &ExecutionPlan,
    max_gpus: Option<usize>,
    cons: &PlacementConstraints,
) -> Result<DeltaPlacement, Unplaceable> {
    let g = &cm.config().gpu;
    let repack = place_constrained(cm, new, max_gpus, cons)?;

    // index the old plan's stamped stages by identity (an unstamped old
    // plan pins nothing and the repack wins trivially)
    let mut old_stages: std::collections::HashMap<
        u64,
        Vec<(crate::profiler::FragmentId, Alloc, Vec<u32>)>,
    > = std::collections::HashMap::new();
    if old.placed_gpus().is_some() {
        for (id, s) in stage_identities(old).into_iter().zip(old.stages()) {
            old_stages.entry(id).or_default().push((
                s.frag,
                s.alloc,
                s.gpus.clone(),
            ));
        }
    }

    let new_ids = stage_identities(new);
    let new_stages: Vec<&super::plan::StagePlan> = new.stages().collect();
    let n_old_gpus = old.placed_gpus().unwrap_or(0);
    let mut usage = vec![GpuUsage::default(); n_old_gpus];
    let mut by_stage: Vec<Vec<u32>> = Vec::with_capacity(new_stages.len());
    let mut pinned_gpus: Vec<Option<Vec<u32>>> =
        Vec::with_capacity(new_stages.len());
    let mut pinned = 0usize;
    let mut repack_migrated = 0usize;
    for (si, s) in new_stages.iter().enumerate() {
        by_stage.push(vec![0; s.alloc.instances as usize]);
        let matched = old_stages
            .get_mut(&new_ids[si])
            .and_then(|bucket| {
                bucket
                    .iter()
                    .position(|(frag, alloc, _)| {
                        *frag == s.frag && *alloc == s.alloc
                    })
                    .map(|i| bucket.swap_remove(i).2)
            })
            // a stage stamped onto failed or suspect hardware cannot
            // stay: unpin it so every instance restarts elsewhere
            .filter(|gpus| !gpus.iter().any(|gpu| cons.is_avoided(*gpu)))
            // degraded hardware: the pins must fit the residual caps
            // on top of what is already pinned there, else the stage
            // sheds off the shrunken GPU
            .filter(|gpus| {
                let mem = cm.instance_mem_mb(s.frag, s.alloc.batch);
                let mut add: std::collections::HashMap<u32, u32> =
                    std::collections::HashMap::new();
                for &gpu in gpus.iter() {
                    *add.entry(gpu).or_insert(0) += 1;
                }
                add.iter().all(|(&gpu, &cnt)| {
                    let u = &usage[gpu as usize];
                    u.share + s.alloc.share * cnt
                        <= cons.share_cap(gpu, g.max_share)
                        && u.mem_mb + mem * cnt as f64
                            <= cons.mem_cap(gpu, g.gpu_mem_mb)
                })
            });
        match matched {
            Some(gpus) => {
                // unchanged stage: pin every instance to its current GPU
                let mem = cm.instance_mem_mb(s.frag, s.alloc.batch);
                for &gpu in &gpus {
                    usage[gpu as usize].share += s.alloc.share;
                    usage[gpu as usize].mem_mb += mem;
                }
                pinned += gpus.len();
                // the repack restarts whatever it did not keep in place
                repack_migrated += gpus.len()
                    - gpu_overlap(&gpus, &repack.by_stage[si]);
                pinned_gpus.push(Some(gpus));
            }
            None => {
                // new or changed stage: all instances restart under
                // either strategy
                repack_migrated += s.alloc.instances as usize;
                pinned_gpus.push(None);
            }
        }
    }

    // FFD the diff into the vacated + residual capacity (same
    // deterministic ordering discipline as `place`)
    let mut items: Vec<(usize, usize, u32, f64)> = Vec::new();
    for (si, s) in new_stages.iter().enumerate() {
        match &pinned_gpus[si] {
            Some(gpus) => by_stage[si] = gpus.clone(),
            None => {
                let mem = cm.instance_mem_mb(s.frag, s.alloc.batch);
                for inst in 0..s.alloc.instances as usize {
                    items.push((si, inst, s.alloc.share, mem));
                }
            }
        }
    }
    let migrated = items.len();
    items.sort_by(|a, b| b.2.cmp(&a.2).then(b.3.total_cmp(&a.3)));
    let hard = |gpu: usize| cons.is_hard(gpu as u32);
    let soft = |gpu: usize| cons.is_soft(gpu as u32);
    let share_cap = |gpu: usize| cons.share_cap(gpu as u32, g.max_share);
    let mem_cap = |gpu: usize| cons.mem_cap(gpu as u32, g.gpu_mem_mb);
    let mut delta_ok = true;
    for (si, inst, share, mem) in items {
        let fits = |i: usize, u: &GpuUsage| {
            u.share + share <= share_cap(i) && u.mem_mb + mem <= mem_cap(i)
        };
        // first fit over healthy bins (soft bins are last resort, same
        // discipline as `place_items`)
        let mut slot = usage
            .iter()
            .enumerate()
            .position(|(i, u)| !hard(i) && !soft(i) && fits(i, u));
        if slot.is_none() {
            let used = usage
                .iter()
                .enumerate()
                .filter(|(i, u)| {
                    !hard(*i)
                        && (!soft(*i) || u.share > 0 || u.mem_mb > 0.0)
                })
                .count();
            if max_gpus.is_some_and(|cap| used >= cap) {
                slot = usage
                    .iter()
                    .enumerate()
                    .position(|(i, u)| soft(i) && !hard(i) && fits(i, u));
                if slot.is_none() {
                    // the repack fit under the cap, so fall back to it
                    delta_ok = false;
                    break;
                }
            } else {
                loop {
                    let id = usage.len();
                    if hard(id)
                        || soft(id)
                        || share > share_cap(id)
                        || mem > mem_cap(id)
                    {
                        usage.push(GpuUsage::default());
                        continue;
                    }
                    usage.push(GpuUsage::default());
                    slot = Some(id);
                    break;
                }
            }
        }
        let gpu = slot.expect("slot resolved above");
        usage[gpu].share += share;
        usage[gpu].mem_mb += mem;
        by_stage[si][inst] = gpu as u32;
    }
    let gpus_used = usage
        .iter()
        .filter(|u| u.share > 0 || u.mem_mb > 0.0)
        .count();
    let repack_gpus = repack.gpus();
    if delta_ok && gpus_used <= repack_gpus {
        Ok(DeltaPlacement {
            placement: Placement { usage, by_stage },
            pinned,
            migrated,
            gpus_used,
            repack_migrated,
            repack_gpus,
            fell_back: false,
        })
    } else {
        // delta packing fragments past the oracle: take the repack
        let total: usize = new_stages
            .iter()
            .map(|s| s.alloc.instances as usize)
            .sum();
        Ok(DeltaPlacement {
            placement: repack,
            pinned: total - repack_migrated,
            migrated: repack_migrated,
            gpus_used: repack_gpus,
            repack_migrated,
            repack_gpus,
            fell_back: true,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::baselines::gslice;
    use crate::coordinator::{ClientId, FragmentSpec};
    use crate::profiler::AllocConstraints;
    use crate::sim::cluster::pack;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn plan(cm: &CostModel, n: u32) -> ExecutionPlan {
        let inc = cm.model_index("inc").unwrap();
        let specs: Vec<FragmentSpec> = (0..n)
            .map(|i| FragmentSpec::single(ClientId(i), inc, 3, 100.0, 30.0))
            .collect();
        gslice(cm, &specs, &AllocConstraints::default())
    }

    #[test]
    fn place_respects_caps_and_covers_every_instance() {
        let cm = cm();
        let p = plan(&cm, 12);
        let placement = place(&cm, &p, None).unwrap();
        let g = &cm.config().gpu;
        for u in &placement.usage {
            assert!(u.share <= g.max_share);
            assert!(u.mem_mb <= g.gpu_mem_mb);
        }
        let stages: Vec<_> = p.stages().collect();
        assert_eq!(placement.by_stage.len(), stages.len());
        for (s, gpus) in stages.iter().zip(&placement.by_stage) {
            assert_eq!(gpus.len(), s.alloc.instances as usize);
        }
        // share conservation
        let placed: u64 =
            placement.usage.iter().map(|u| u.share as u64).sum();
        assert_eq!(placed, p.total_share() as u64);
    }

    #[test]
    fn place_matches_pack_oracle_gpu_count() {
        let cm = cm();
        for n in [1u32, 4, 12, 40] {
            let p = plan(&cm, n);
            let ours = place(&cm, &p, None).unwrap();
            let oracle = pack(&cm, &p, None).unwrap();
            assert_eq!(ours.gpus(), oracle.gpus, "n={n}");
            // the plan-level placement-backed count (fallback path for
            // unstamped plans) agrees too
            assert_eq!(p.gpus(&cm), Some(oracle.gpus), "n={n}");
            assert_eq!(
                ours.fragmentation(cm.config().gpu.max_share),
                oracle.fragmentation(cm.config().gpu.max_share),
                "n={n}"
            );
        }
    }

    #[test]
    fn cluster_cap_is_reported() {
        let cm = cm();
        let big = plan(&cm, 40);
        let err = place(&cm, &big, Some(1)).unwrap_err();
        assert!(err.cluster_full);
        assert!(place(&cm, &big, None).is_ok());
    }

    #[test]
    fn stamping_roundtrips_through_the_plan() {
        let cm = cm();
        let mut p = plan(&cm, 12);
        let placement = place(&cm, &p, None).unwrap();
        assert_eq!(p.placed_gpus(), None);
        stamp(&mut p, &placement);
        assert_eq!(p.placed_gpus(), Some(placement.gpus()));
        assert_eq!(p.gpus(&cm), Some(placement.gpus()));
        let usage = stamped_usage(&cm, &p).unwrap();
        assert_eq!(usage.len(), placement.usage.len());
        for (a, b) in usage.iter().zip(&placement.usage) {
            assert_eq!(a.share, b.share);
            assert!((a.mem_mb - b.mem_mb).abs() < 1e-6);
        }
    }

    #[test]
    fn mem_lower_bound_scales_with_demand() {
        let cm = cm();
        let small = gpus_mem_lower_bound(&cm, &plan(&cm, 4));
        let large = gpus_mem_lower_bound(&cm, &plan(&cm, 40));
        assert!(large >= small);
        assert_eq!(gpus_mem_lower_bound(&cm, &ExecutionPlan::default()), 0);
        // never above what a real placement needs
        let p = plan(&cm, 40);
        let placed = place(&cm, &p, None).unwrap();
        assert!(gpus_mem_lower_bound(&cm, &p) <= placed.gpus());
    }

    #[test]
    fn delta_identical_plan_pins_everything() {
        let cm = cm();
        let mut old = plan(&cm, 12);
        let placement = place(&cm, &old, None).unwrap();
        stamp(&mut old, &placement);
        let new = old.clone();
        let d = place_delta(&cm, &old, &new, None, &[]).unwrap();
        assert!(!d.fell_back);
        assert_eq!(d.migrated, 0);
        let total: usize =
            new.stages().map(|s| s.alloc.instances as usize).sum();
        assert_eq!(d.pinned, total);
        assert_eq!(d.gpus_used, placement.gpus());
        // pinned assignments are byte-identical to the old stamps
        for (old_s, gpus) in old.stages().zip(&d.placement.by_stage) {
            assert_eq!(&old_s.gpus, gpus);
        }
    }

    #[test]
    fn delta_never_exceeds_repack_and_respects_caps() {
        let cm = cm();
        let g = cm.config().gpu.clone();
        let mut old = plan(&cm, 24);
        let placement = place(&cm, &old, None).unwrap();
        stamp(&mut old, &placement);
        // grow the fleet: 6 more clients — old sets unchanged, new set
        // packs into the residual capacity
        let mut new = plan(&cm, 30);
        assert_eq!(new.placed_gpus(), None);
        let d = place_delta(&cm, &old, &new, None, &[]).unwrap();
        let total: usize =
            new.stages().map(|s| s.alloc.instances as usize).sum();
        assert_eq!(d.pinned + d.migrated, total);
        assert!(d.migrated <= d.repack_migrated);
        assert!(d.gpus_used <= d.repack_gpus);
        // caps hold on every (possibly partially vacated) GPU
        for u in &d.placement.usage {
            assert!(u.share <= g.max_share);
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6);
        }
        // stamping the delta placement round-trips
        stamp(&mut new, &d.placement);
        assert!(new.placed_gpus().is_some());
    }

    #[test]
    fn delta_unstamped_old_plan_falls_back_to_repack() {
        let cm = cm();
        let old = plan(&cm, 8); // never stamped
        let new = plan(&cm, 8);
        let d = place_delta(&cm, &old, &new, None, &[]).unwrap();
        assert!(d.fell_back || d.migrated == d.repack_migrated);
        assert_eq!(d.gpus_used, d.repack_gpus);
    }

    #[test]
    fn stage_identities_are_unique_and_stable_under_perturbation() {
        let cm = cm();
        let inc = cm.model_index("inc").unwrap();
        let specs: Vec<FragmentSpec> = (0..6)
            .map(|i| {
                FragmentSpec::single(ClientId(i), inc, 3, 100.0 + i as f64, 30.0)
            })
            .collect();
        let a = gslice(&cm, &specs, &AllocConstraints::default());
        let ids_a = stage_identities(&a);
        assert_eq!(ids_a.len(), a.stages().count());
        let mut dedup = ids_a.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ids_a.len(), "identities collide");
        // a rate/budget move keeps the identity (same clients)
        let mut specs_b = specs.clone();
        for s in &mut specs_b {
            s.budget_ms += 5.0;
            s.rate_rps *= 1.5;
        }
        let b = gslice(&cm, &specs_b, &AllocConstraints::default());
        if b.stages().count() == a.stages().count() {
            assert_eq!(ids_a, stage_identities(&b));
        }
    }

    #[test]
    fn avoided_gpus_never_receive_instances() {
        let cm = cm();
        let g = cm.config().gpu.clone();
        let mut old = plan(&cm, 24);
        let placement = place(&cm, &old, None).unwrap();
        stamp(&mut old, &placement);
        assert!(placement.gpus() >= 2, "need a multi-GPU packing");

        // plain avoid-aware placement: blocked ids are skipped entirely
        let p = place_avoiding(&cm, &old, None, &[0, 2]).unwrap();
        for gpus in &p.by_stage {
            assert!(!gpus.contains(&0) && !gpus.contains(&2));
        }
        for (i, u) in p.usage.iter().enumerate() {
            if i == 0 || i == 2 {
                assert_eq!(u.share, 0, "blocked id {i} was used");
            }
            assert!(u.share <= g.max_share);
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6);
        }

        // delta replacement around a failed GPU: stages pinned there
        // are evicted, nothing lands back on the dead id
        let new = old.clone();
        let d = place_delta(&cm, &old, &new, None, &[0]).unwrap();
        for gpus in &d.placement.by_stage {
            assert!(!gpus.contains(&0), "instance placed on failed GPU");
        }
        // everything that lived on GPU 0 migrated
        let evicted: usize = old
            .stages()
            .map(|s| s.gpus.iter().filter(|&&gp| gp == 0).count())
            .sum();
        assert!(evicted > 0, "seed packing left GPU 0 empty");
        assert!(d.migrated >= evicted);
        for u in &d.placement.usage {
            assert!(u.share <= g.max_share);
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6);
        }
    }

    #[test]
    fn empty_constraints_are_byte_identical() {
        let cm = cm();
        let mut old = plan(&cm, 24);
        let base = place(&cm, &old, None).unwrap();
        let cons = PlacementConstraints::default();
        assert!(cons.is_empty());
        let constrained = place_constrained(&cm, &old, None, &cons).unwrap();
        assert_eq!(base.usage, constrained.usage);
        assert_eq!(base.by_stage, constrained.by_stage);
        stamp(&mut old, &base);
        let new = plan(&cm, 30);
        let d0 = place_delta(&cm, &old, &new, None, &[]).unwrap();
        let d1 =
            place_delta_constrained(&cm, &old, &new, None, &cons).unwrap();
        assert_eq!(d0.placement.usage, d1.placement.usage);
        assert_eq!(d0.placement.by_stage, d1.placement.by_stage);
        assert_eq!(d0.pinned, d1.pinned);
        assert_eq!(d0.migrated, d1.migrated);
        assert_eq!(d0.fell_back, d1.fell_back);
    }

    #[test]
    fn soft_avoided_gpus_are_vacated_when_capacity_allows() {
        let cm = cm();
        let g = cm.config().gpu.clone();
        let mut old = plan(&cm, 24);
        let base = place(&cm, &old, None).unwrap();
        stamp(&mut old, &base);
        assert!(base.gpus() >= 2, "need a multi-GPU packing");
        let cons = PlacementConstraints {
            soft_avoid: vec![0],
            ..Default::default()
        };
        // uncapped: the strict pass wins, the suspect ends up empty
        let p = place_constrained(&cm, &old, None, &cons).unwrap();
        for gpus in &p.by_stage {
            assert!(!gpus.contains(&0), "suspect GPU received an instance");
        }
        // delta against the stamped old plan: everything on the suspect
        // migrates off, bounded by the repack oracle
        let new = old.clone();
        let d =
            place_delta_constrained(&cm, &old, &new, None, &cons).unwrap();
        for gpus in &d.placement.by_stage {
            assert!(!gpus.contains(&0), "suspect GPU kept an instance");
        }
        let evicted: usize = old
            .stages()
            .map(|s| s.gpus.iter().filter(|&&gp| gp == 0).count())
            .sum();
        assert!(evicted > 0, "seed packing left GPU 0 empty");
        assert!(d.migrated >= evicted);
        assert!(d.migrated <= d.repack_migrated);
        assert!(d.gpus_used <= d.repack_gpus);
        for u in &d.placement.usage {
            assert!(u.share <= g.max_share);
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6);
        }
    }

    #[test]
    fn soft_avoided_gpu_is_last_resort_under_the_cap() {
        let cm = cm();
        let p = plan(&cm, 24);
        let base = place(&cm, &p, None).unwrap();
        let k = base.gpus();
        assert!(k >= 2, "need a multi-GPU packing");
        // one healthy bin short of the demand: both the plain packing
        // and the strict (suspect-excluded) pass die on the cap...
        assert!(place(&cm, &p, Some(k - 1)).unwrap_err().cluster_full);
        let cons = PlacementConstraints {
            soft_avoid: vec![0],
            ..Default::default()
        };
        // ...so the lenient pass spills the overflow onto the suspect
        let placed = place_constrained(&cm, &p, Some(k - 1), &cons).unwrap();
        let on_suspect: usize = placed
            .by_stage
            .iter()
            .map(|gpus| gpus.iter().filter(|&&gp| gp == 0).count())
            .sum();
        assert!(on_suspect > 0, "last-resort spill never happened");
    }

    #[test]
    fn degraded_gpus_offer_only_residual_capacity() {
        let cm = cm();
        let g = cm.config().gpu.clone();
        let p = plan(&cm, 24);
        let loss = g.max_share / 2;
        let cons = PlacementConstraints {
            share_loss: [(0u32, loss)].into_iter().collect(),
            mem_loss_mb: [(0u32, g.gpu_mem_mb / 2.0)].into_iter().collect(),
            ..Default::default()
        };
        let placed = place_constrained(&cm, &p, None, &cons).unwrap();
        assert!(placed.usage[0].share <= g.max_share - loss);
        assert!(placed.usage[0].mem_mb <= g.gpu_mem_mb / 2.0 + 1e-6);
        for u in &placed.usage {
            assert!(u.share <= g.max_share);
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6);
        }
        // a fully degraded GPU behaves like a hard avoid
        let dead = PlacementConstraints {
            share_loss: [(0u32, g.max_share)].into_iter().collect(),
            ..Default::default()
        };
        let placed = place_constrained(&cm, &p, None, &dead).unwrap();
        assert_eq!(placed.usage[0].share, 0, "no share fits a dead cap");
    }

    #[test]
    fn fragmentation_and_excess_metrics() {
        let empty = Placement::default();
        assert_eq!(empty.fragmentation(100), 0.0);
        assert_eq!(empty.excess_over(0), 0.0);
        let p = Placement {
            usage: vec![
                GpuUsage { share: 100, mem_mb: 0.0 },
                GpuUsage { share: 50, mem_mb: 0.0 },
            ],
            by_stage: vec![],
        };
        assert!((p.fragmentation(100) - 0.25).abs() < 1e-12);
        assert!((p.excess_over(1) - 0.5).abs() < 1e-12);
        assert_eq!(p.excess_over(2), 0.0);
        assert_eq!(p.excess_over(5), 0.0);
    }
}
