//! §4.2 — DNN fragment grouping as balanced graph partitioning.
//!
//! Fragments are nodes of a complete graph; edge weights are the weighted
//! Euclidean distance between the property vectors `⟨p, t, q⟩`.  The
//! grouping problem is a variant of balanced graph partitioning: divide
//! the nodes into `K = ⌈n / group_size⌉` (nearly) equal, disjoint subsets
//! minimising Eq. (1) — the within-group edge-weight variance plus the
//! total cross-group edge weight.  We follow the Fennel-style greedy:
//! seed `K` groups with random fragments, then assign each remaining
//! fragment to the group with the least objective increase subject to
//! the balance cap.
//!
//! Across replan triggers the scheduler uses the delta-aware variant
//! ([`group_fragments_incremental`]): unchanged demands replay the
//! previous trigger's groups byte-identically, and only new/changed
//! fragments go through the greedy — with the from-scratch path kept as
//! the fallback and audit oracle.
//!
//! Grouping never crosses models (each call sees one model's merged
//! slice), so each [`GroupState`] is owned by exactly one per-model
//! planner shard: sharded planning replays grouping state inside each
//! shard worker with no cross-shard locking, and the per-shard results
//! are byte-identical to a sequential pass over the same slices.

use std::collections::{BTreeMap, HashMap};

use anyhow::Result;

use super::fragment::{ClientId, FragmentSpec};
use crate::util::{Json, Rng};

/// Factor weights for the distance on `⟨p, t, q⟩` (§5.6 explores these;
/// equal weights are within ~4% of optimal).
#[derive(Debug, Clone, Copy)]
pub struct FactorWeights {
    pub p: f64,
    pub t: f64,
    pub q: f64,
}

impl Default for FactorWeights {
    fn default() -> Self {
        Self { p: 1.0, t: 1.0, q: 1.0 }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct GroupOptions {
    /// Target group size (paper default 5; the knee of Fig 16a).
    pub group_size: usize,
    pub weights: FactorWeights,
    pub seed: u64,
    /// Delta-aware grouping across triggers: replay the previous
    /// trigger's groups where members are unchanged and greedily place
    /// only the new/changed fragments ([`group_fragments_incremental`],
    /// used by the scheduler when its own `incremental` flag is on).
    /// Unlike the merge/DP/placement reuse this is a *heuristic* —
    /// replayed groups are byte-identical for unchanged demands, but a
    /// perturbed trigger's groups may differ from the from-scratch
    /// greedy (bounded by `churn_threshold`/`epsilon` below).  `false`
    /// pins the scheduler to the scratch greedy every trigger.
    pub incremental: bool,
    /// Fraction of a model's fragments that may change (arrive, depart
    /// or move their property vector) per trigger before the
    /// incremental path falls back to the from-scratch greedy.
    pub churn_threshold: f64,
    /// Allowed relative Eq.-(1) objective drift of the incremental
    /// grouping vs the from-scratch oracle when the audit runs (slices
    /// of ≤ `audit_limit` fragments); past it the slice falls back.
    pub epsilon: f64,
    /// Slice size up to which every perturbed incremental grouping is
    /// audited against the scratch greedy (cheap insurance at small n);
    /// above it the audit would cost exactly what the delta path saves,
    /// so large slices rely on the churn threshold alone.  Test hook:
    /// `usize::MAX` forces the audit, `0` disables it.
    pub audit_limit: usize,
    /// Largest n for which the dense similarity matrix is built
    /// ([`SimTable`]); above it pairwise similarities are evaluated on
    /// the fly.  Groups are identical either side — only the lookup's
    /// build cost changes.  Injectable for tests (`0` forces the lazy
    /// path).
    pub dense_limit: usize,
}

impl Default for GroupOptions {
    fn default() -> Self {
        Self {
            group_size: 5,
            weights: FactorWeights::default(),
            seed: 0xF3A7,
            incremental: true,
            churn_threshold: 0.5,
            epsilon: 0.05,
            audit_limit: 256,
            dense_limit: DENSE_SIM_LIMIT,
        }
    }
}

/// Edge weight = *similarity* of two fragments: the paper assigns edge
/// weights "based on the similarity of the fragments ... using the
/// weighted Euclidean distance between the property vectors" — i.e. a
/// decreasing transform of the (normalised, weighted) distance, so that
/// minimising external edge weight keeps similar fragments together.
fn similarity(
    a: &[f64; 3],
    b: &[f64; 3],
    w: &FactorWeights,
    scale: &[f64; 3],
) -> f64 {
    let d = |i: usize, wi: f64| {
        let s = if scale[i] > 0.0 { scale[i] } else { 1.0 };
        wi * ((a[i] - b[i]) / s).powi(2)
    };
    let dist = (d(0, w.p) + d(1, w.t) + d(2, w.q)).sqrt();
    1.0 / (1.0 + dist)
}

/// Per-dimension ranges used for normalisation.
fn scales(props: &[[f64; 3]]) -> [f64; 3] {
    let mut s = [0.0f64; 3];
    for i in 0..3 {
        let min = props.iter().map(|p| p[i]).fold(f64::INFINITY, f64::min);
        let max = props.iter().map(|p| p[i]).fold(f64::NEG_INFINITY, f64::max);
        s[i] = max - min;
    }
    s
}

/// The Eq.-(1) objective of a complete grouping (used by tests and the
/// optimal-grouping baseline): Σ_k var(internal edges of k) + Σ external
/// edge weights.
pub fn objective(
    specs: &[FragmentSpec],
    groups: &[Vec<usize>],
    w: &FactorWeights,
) -> f64 {
    let props: Vec<[f64; 3]> =
        specs.iter().map(FragmentSpec::property_vector).collect();
    let sc = scales(&props);
    let mut in_group = vec![usize::MAX; specs.len()];
    for (k, g) in groups.iter().enumerate() {
        for &i in g {
            in_group[i] = k;
        }
    }
    let mut var_sum = 0.0;
    for g in groups {
        let mut edges = Vec::new();
        for (ai, &i) in g.iter().enumerate() {
            for &j in &g[ai + 1..] {
                edges.push(similarity(&props[i], &props[j], w, &sc));
            }
        }
        if !edges.is_empty() {
            let mean = edges.iter().sum::<f64>() / edges.len() as f64;
            var_sum += edges.iter().map(|e| (e - mean).powi(2)).sum::<f64>()
                / edges.len() as f64;
        }
    }
    let mut ext = 0.0;
    for i in 0..specs.len() {
        for j in i + 1..specs.len() {
            if in_group[i] != in_group[j] {
                ext += similarity(&props[i], &props[j], w, &sc);
            }
        }
    }
    var_sum + ext
}

/// Pairwise similarity lookup: a dense symmetric matrix when it fits in
/// a modest footprint (one similarity evaluation per pair for the whole
/// greedy run), falling back to on-the-fly evaluation at larger n (the
/// matrix would be O(n²) memory).  The seed re-evaluated every internal
/// edge of every candidate group per assignment — O(n · K · g²)
/// similarity calls; with this table plus the running-moment group stats
/// below, each candidate assignment costs O(group) lookups.
const DENSE_SIM_LIMIT: usize = 2048; // 2048² f64 = 32 MiB

enum SimTable<'a> {
    Dense { n: usize, m: Vec<f64> },
    Lazy { props: &'a [[f64; 3]], w: FactorWeights, sc: [f64; 3] },
}

impl<'a> SimTable<'a> {
    fn new(
        props: &'a [[f64; 3]],
        w: FactorWeights,
        sc: [f64; 3],
        dense_limit: usize,
    ) -> SimTable<'a> {
        let n = props.len();
        if n > dense_limit {
            return SimTable::Lazy { props, w, sc };
        }
        let mut m = vec![0.0; n * n];
        for i in 0..n {
            for j in i + 1..n {
                let s = similarity(&props[i], &props[j], &w, &sc);
                m[i * n + j] = s;
                m[j * n + i] = s;
            }
        }
        SimTable::Dense { n, m }
    }

    #[inline]
    fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            SimTable::Dense { n, m } => m[i * n + j],
            SimTable::Lazy { props, w, sc } => {
                similarity(&props[i], &props[j], w, sc)
            }
        }
    }
}

/// Running moments of a group's internal edge weights; variance in O(1)
/// from (Σe, Σe², count) instead of rebuilding the edge list.  Public so
/// the incremental grouping state ([`GroupState`]) can persist them
/// across triggers and the scheduler can serialize them.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GroupStats {
    pub sum: f64,
    pub sumsq: f64,
    pub count: usize,
}

impl GroupStats {
    #[inline]
    fn var(sum: f64, sumsq: f64, count: usize) -> f64 {
        if count == 0 {
            return 0.0;
        }
        let mean = sum / count as f64;
        // E[x²] − E[x]²; clamp the tiny negative values FP can produce
        (sumsq / count as f64 - mean * mean).max(0.0)
    }
}

/// Greedy balanced grouping (§4.2).  Returns index groups over `specs`.
/// All specs must belong to the same model (the scheduler splits by
/// model first — §6 "Heterogeneous models").
pub fn group_fragments(
    specs: &[FragmentSpec],
    opts: &GroupOptions,
) -> Vec<Vec<usize>> {
    let n = specs.len();
    if n == 0 {
        return Vec::new();
    }
    debug_assert!(
        specs.windows(2).all(|w| w[0].model == w[1].model),
        "grouping expects same-model fragments"
    );
    let gs = opts.group_size.max(1);
    let k = n.div_ceil(gs);
    if k <= 1 {
        return vec![(0..n).collect()];
    }
    let cap = n.div_ceil(k);

    let props: Vec<[f64; 3]> =
        specs.iter().map(FragmentSpec::property_vector).collect();
    let sc = scales(&props);
    let sim = SimTable::new(&props, opts.weights, sc, opts.dense_limit);

    // (a) seed K groups with random fragments
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Rng::seed_from_u64(opts.seed);
    rng.shuffle(&mut order);
    let mut groups: Vec<Vec<usize>> =
        order[..k].iter().map(|&i| vec![i]).collect();
    let mut stats = vec![GroupStats::default(); k];

    // (b) assign the rest minimising the objective increase:
    //   Δ = Δvar(internal edges of k) − Σ edges(f ↔ members of k)
    // (the external-edge term decreases exactly by the edges absorbed).
    // Δvar comes from the running moments: O(group) edge lookups per
    // candidate, no edge-list rebuild.
    for &i in &order[k..] {
        // (group idx, delta, Σ new edges, Σ new edges²)
        let mut best: Option<(usize, f64, f64, f64)> = None;
        for (gk, g) in groups.iter().enumerate() {
            if g.len() >= cap {
                continue;
            }
            let mut esum = 0.0;
            let mut esumsq = 0.0;
            for &j in g {
                let e = sim.get(i, j);
                esum += e;
                esumsq += e * e;
            }
            let st = stats[gk];
            let var_before = GroupStats::var(st.sum, st.sumsq, st.count);
            let var_after = GroupStats::var(
                st.sum + esum,
                st.sumsq + esumsq,
                st.count + g.len(),
            );
            let delta = var_after - var_before - esum;
            if best.map_or(true, |(_, b, _, _)| delta < b) {
                best = Some((gk, delta, esum, esumsq));
            }
        }
        let (gk, _, esum, esumsq) =
            best.expect("cap * k >= n so some group has room");
        stats[gk].sum += esum;
        stats[gk].sumsq += esumsq;
        stats[gk].count += groups[gk].len();
        groups[gk].push(i);
    }
    groups
}

// -- incremental grouping (trigger-to-trigger, §4.2 delta-aware) -----------

/// One persisted member of a group: its identity across triggers (the
/// merged fragment's *sorted* client set — stable no matter how merging
/// ordered the clients) and the property vector it was grouped under.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupMember {
    pub key: Vec<ClientId>,
    pub props: [f64; 3],
}

/// Per-model grouping state carried across triggers in `ReplanContext`:
/// the previous trigger's groups (member identities + property vectors,
/// in assignment order), the normalisation scales they were grouped
/// under, and each group's running edge moments.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupState {
    pub scales: [f64; 3],
    pub groups: Vec<Vec<GroupMember>>,
    pub stats: Vec<GroupStats>,
}

/// What [`group_fragments_incremental`] did for one model slice.
#[derive(Debug, Clone, Default)]
pub struct GroupDelta {
    /// Index groups over the input specs (same shape as
    /// [`group_fragments`]).
    pub groups: Vec<Vec<usize>>,
    /// Groups replayed byte-identically from the previous trigger.
    pub replayed: usize,
    /// Fragments that went through the greedy (new, moved, or — on
    /// fallback — all of them).
    pub regrouped: usize,
    /// The from-scratch greedy ran instead of the delta path (churn
    /// over threshold, ε-audit breach, or degenerate identities).
    pub fell_back: bool,
}

#[inline]
fn props_eq(a: &[f64; 3], b: &[f64; 3]) -> bool {
    (0..3).all(|i| a[i].to_bits() == b[i].to_bits())
}

/// Internal-edge moments of one group, rebuilt pairwise.
fn rebuild_stats(
    members: &[usize],
    props: &[[f64; 3]],
    w: &FactorWeights,
    sc: &[f64; 3],
) -> GroupStats {
    let mut st = GroupStats::default();
    for (ai, &i) in members.iter().enumerate() {
        for &j in &members[ai + 1..] {
            let e = similarity(&props[i], &props[j], w, sc);
            st.sum += e;
            st.sumsq += e * e;
            st.count += 1;
        }
    }
    st
}

fn sorted_key(spec: &FragmentSpec) -> Vec<ClientId> {
    let mut k = spec.clients.clone();
    k.sort_unstable();
    k
}

impl GroupState {
    /// Snapshot an index grouping of `specs` (used after a from-scratch
    /// run so the *next* trigger can go delta-aware).
    pub fn from_groups(
        specs: &[FragmentSpec],
        groups: &[Vec<usize>],
        opts: &GroupOptions,
    ) -> GroupState {
        let props: Vec<[f64; 3]> =
            specs.iter().map(FragmentSpec::property_vector).collect();
        let sc = scales(&props);
        GroupState {
            scales: sc,
            stats: groups
                .iter()
                .map(|g| rebuild_stats(g, &props, &opts.weights, &sc))
                .collect(),
            groups: groups
                .iter()
                .map(|g| {
                    g.iter()
                        .map(|&i| GroupMember {
                            key: sorted_key(&specs[i]),
                            props: props[i],
                        })
                        .collect()
                })
                .collect(),
        }
    }

    /// JSON form for replan-context persistence (exact float round-trip
    /// through the shortest-repr printer, like `FragmentSpec::to_json`).
    pub fn to_json(&self) -> Json {
        let num3 = |a: &[f64; 3]| {
            Json::Arr(a.iter().map(|&x| Json::Num(x)).collect())
        };
        let mut o = BTreeMap::new();
        o.insert("scales".into(), num3(&self.scales));
        o.insert(
            "groups".into(),
            Json::Arr(
                self.groups
                    .iter()
                    .map(|g| {
                        Json::Arr(
                            g.iter()
                                .map(|m| {
                                    let mut mo = BTreeMap::new();
                                    mo.insert(
                                        "key".into(),
                                        Json::Arr(
                                            m.key
                                                .iter()
                                                .map(|c| Json::Num(c.0 as f64))
                                                .collect(),
                                        ),
                                    );
                                    mo.insert("props".into(), num3(&m.props));
                                    Json::Obj(mo)
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        );
        o.insert(
            "stats".into(),
            Json::Arr(
                self.stats
                    .iter()
                    .map(|s| {
                        let mut so = BTreeMap::new();
                        so.insert("sum".into(), Json::Num(s.sum));
                        so.insert("sumsq".into(), Json::Num(s.sumsq));
                        so.insert("count".into(), Json::Num(s.count as f64));
                        Json::Obj(so)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    /// Inverse of [`Self::to_json`].
    pub fn from_json(v: &Json) -> Result<GroupState> {
        let arr3 = |v: &Json| -> Result<[f64; 3]> {
            let f = v.as_f64_vec()?;
            anyhow::ensure!(f.len() == 3, "expected 3 floats, got {}", f.len());
            Ok([f[0], f[1], f[2]])
        };
        Ok(GroupState {
            scales: arr3(v.get("scales")?)?,
            groups: v
                .get("groups")?
                .as_arr()?
                .iter()
                .map(|g| {
                    g.as_arr()?
                        .iter()
                        .map(|m| {
                            Ok(GroupMember {
                                key: m
                                    .get("key")?
                                    .as_arr()?
                                    .iter()
                                    .map(|c| {
                                        Ok(ClientId(c.as_usize()? as u32))
                                    })
                                    .collect::<Result<_>>()?,
                                props: arr3(m.get("props")?)?,
                            })
                        })
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<Vec<_>>>()?,
            stats: v
                .get("stats")?
                .as_arr()?
                .iter()
                .map(|s| {
                    Ok(GroupStats {
                        sum: s.get("sum")?.as_f64()?,
                        sumsq: s.get("sumsq")?.as_f64()?,
                        count: s.get("count")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// Delta-aware §4.2 grouping across triggers.
///
/// Diffs `specs` against `prev` by member identity (sorted client set)
/// and bitwise property vector:
///
/// 1. **Pure replay** — nothing changed: the previous groups are
///    returned byte-identically (`regrouped == 0`), no audit.
/// 2. **Delta path** — vacate departed/changed members (rebuilding the
///    affected groups' running moments; all moments rebuild if the
///    normalisation scales moved), then greedily insert the new/changed
///    fragments — in identity-key order, so insertion is independent of
///    `n` — into residual capacity under the same Δ-objective rule as
///    the scratch greedy, opening a fresh group only when none has
///    room.
/// 3. **Fallback** — churn above `opts.churn_threshold`, or (for slices
///    of ≤ `opts.audit_limit`) the Eq.-(1) objective drifting more than
///    `opts.epsilon` past the from-scratch greedy, reruns
///    [`group_fragments`] from scratch.
///
/// Returns the delta plus the state to persist for the next trigger.
/// `prev: None` (cold trigger) is the scratch path without counting as
/// a fallback.
pub fn group_fragments_incremental(
    specs: &[FragmentSpec],
    opts: &GroupOptions,
    prev: Option<&GroupState>,
) -> (GroupDelta, GroupState) {
    let n = specs.len();
    if n == 0 {
        return (
            GroupDelta::default(),
            GroupState { scales: [0.0; 3], groups: Vec::new(), stats: Vec::new() },
        );
    }
    let scratch = |fell_back: bool| {
        let groups = group_fragments(specs, opts);
        let state = GroupState::from_groups(specs, &groups, opts);
        let delta = GroupDelta {
            replayed: 0,
            regrouped: n,
            fell_back,
            groups,
        };
        (delta, state)
    };
    let Some(prev) = prev else {
        return scratch(false);
    };

    let props: Vec<[f64; 3]> =
        specs.iter().map(FragmentSpec::property_vector).collect();
    let keys: Vec<Vec<ClientId>> = specs.iter().map(sorted_key).collect();
    let mut by_key: HashMap<&[ClientId], usize> = HashMap::with_capacity(n);
    for (i, k) in keys.iter().enumerate() {
        if by_key.insert(k.as_slice(), i).is_some() {
            // duplicate identities can't be diffed — degenerate input
            return scratch(true);
        }
    }

    // diff: per previous group, the surviving members (prev order) and
    // whether the group is intact; count departures (gone or moved)
    let mut matched = vec![false; n];
    let mut departed = 0usize;
    // (index into prev.groups/prev.stats, surviving members, intact)
    let mut survivors: Vec<(usize, Vec<usize>, bool)> = Vec::new();
    for (gi, g) in prev.groups.iter().enumerate() {
        let mut cur = Vec::with_capacity(g.len());
        let mut intact = true;
        for m in g {
            match by_key.get(m.key.as_slice()) {
                Some(&i) if props_eq(&props[i], &m.props) => {
                    cur.push(i);
                    matched[i] = true;
                }
                _ => {
                    intact = false;
                    departed += 1;
                }
            }
        }
        if !cur.is_empty() {
            survivors.push((gi, cur, intact));
        }
    }
    let mut changed: Vec<usize> = (0..n).filter(|&i| !matched[i]).collect();

    if changed.is_empty() && departed == 0 {
        // pure replay: groups (and therefore every downstream
        // `group_signature`) are byte-identical to the previous trigger
        let groups: Vec<Vec<usize>> =
            survivors.into_iter().map(|(_, g, _)| g).collect();
        let delta = GroupDelta {
            replayed: groups.len(),
            regrouped: 0,
            fell_back: false,
            groups,
        };
        return (delta, prev.clone());
    }

    if (changed.len() + departed) as f64 > opts.churn_threshold * n as f64 {
        return scratch(true);
    }

    let sc = scales(&props);
    let scales_same = props_eq(&sc, &prev.scales);
    let mut groups: Vec<Vec<usize>> = Vec::with_capacity(survivors.len());
    let mut stats: Vec<GroupStats> = Vec::with_capacity(survivors.len());
    let mut pristine: Vec<bool> = Vec::with_capacity(survivors.len());
    for (gi, g, intact) in survivors {
        stats.push(if intact && scales_same {
            prev.stats[gi]
        } else {
            rebuild_stats(&g, &props, &opts.weights, &sc)
        });
        pristine.push(intact);
        groups.push(g);
    }

    // greedy insertion in identity-key order (n-independent, unlike the
    // scratch seeding shuffle); direct similarity calls — no O(n²)
    // table, which is where the delta path's speedup comes from
    changed.sort_unstable_by(|&a, &b| keys[a].cmp(&keys[b]));
    let gs = opts.group_size.max(1);
    let cap = n.div_ceil(n.div_ceil(gs));
    for &i in &changed {
        let mut best: Option<(usize, f64, f64, f64)> = None;
        for (gk, g) in groups.iter().enumerate() {
            if g.len() >= cap {
                continue;
            }
            let mut esum = 0.0;
            let mut esumsq = 0.0;
            for &j in g {
                let e = similarity(&props[i], &props[j], &opts.weights, &sc);
                esum += e;
                esumsq += e * e;
            }
            let st = stats[gk];
            let var_before = GroupStats::var(st.sum, st.sumsq, st.count);
            let var_after = GroupStats::var(
                st.sum + esum,
                st.sumsq + esumsq,
                st.count + g.len(),
            );
            let delta = var_after - var_before - esum;
            if best.map_or(true, |(_, b, _, _)| delta < b) {
                best = Some((gk, delta, esum, esumsq));
            }
        }
        match best {
            Some((gk, _, esum, esumsq)) => {
                stats[gk].sum += esum;
                stats[gk].sumsq += esumsq;
                stats[gk].count += groups[gk].len();
                groups[gk].push(i);
                pristine[gk] = false;
            }
            None => {
                groups.push(vec![i]);
                stats.push(GroupStats::default());
                pristine.push(false);
            }
        }
    }

    // ε-audit against the scratch oracle where it's cheap enough
    if opts.audit_limit > 0 && n <= opts.audit_limit {
        let oracle = group_fragments(specs, opts);
        let inc_obj = objective(specs, &groups, &opts.weights);
        let scr_obj = objective(specs, &oracle, &opts.weights);
        if inc_obj > scr_obj * (1.0 + opts.epsilon) + 1e-9 {
            let state = GroupState::from_groups(specs, &oracle, opts);
            let delta = GroupDelta {
                replayed: 0,
                regrouped: n,
                fell_back: true,
                groups: oracle,
            };
            return (delta, state);
        }
    }

    let state = GroupState {
        scales: sc,
        groups: groups
            .iter()
            .map(|g| {
                g.iter()
                    .map(|&i| GroupMember {
                        key: keys[i].clone(),
                        props: props[i],
                    })
                    .collect()
            })
            .collect(),
        stats,
    };
    let delta = GroupDelta {
        replayed: pristine.iter().filter(|&&p| p).count(),
        regrouped: changed.len(),
        fell_back: false,
        groups,
    };
    (delta, state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fragment::ClientId;

    fn spec(i: u32, p: usize, t: f64, q: f64) -> FragmentSpec {
        FragmentSpec::single(ClientId(i), 0, p, t, q)
    }

    fn cluster_specs() -> Vec<FragmentSpec> {
        // two obvious clusters: (p=2, t≈60) and (p=8, t≈120)
        let mut v = Vec::new();
        for i in 0..5 {
            v.push(spec(i, 2, 60.0 + i as f64, 30.0));
        }
        for i in 5..10 {
            v.push(spec(i, 8, 120.0 + i as f64, 30.0));
        }
        v
    }

    #[test]
    fn groups_are_balanced_disjoint_cover() {
        let specs = cluster_specs();
        let groups =
            group_fragments(&specs, &GroupOptions { group_size: 5, ..Default::default() });
        assert_eq!(groups.len(), 2);
        let mut all: Vec<usize> = groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for g in &groups {
            assert!(g.len() <= 5);
        }
    }

    #[test]
    fn similar_fragments_group_together() {
        let specs = cluster_specs();
        let groups =
            group_fragments(&specs, &GroupOptions { group_size: 5, ..Default::default() });
        for g in &groups {
            let ps: Vec<usize> = g.iter().map(|&i| specs[i].p).collect();
            assert!(
                ps.iter().all(|&p| p == ps[0]),
                "mixed cluster in group: {ps:?}"
            );
        }
    }

    #[test]
    fn single_group_when_few_fragments() {
        let specs = cluster_specs()[..4].to_vec();
        let groups = group_fragments(&specs, &GroupOptions::default());
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].len(), 4);
    }

    #[test]
    fn empty_input() {
        assert!(group_fragments(&[], &GroupOptions::default()).is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let specs = cluster_specs();
        let a = group_fragments(&specs, &GroupOptions::default());
        let b = group_fragments(&specs, &GroupOptions::default());
        assert_eq!(a, b);
    }

    #[test]
    fn objective_prefers_clustered_grouping() {
        let specs = cluster_specs();
        let w = FactorWeights::default();
        let clustered = vec![vec![0, 1, 2, 3, 4], vec![5, 6, 7, 8, 9]];
        let mixed = vec![vec![0, 1, 5, 6, 7], vec![2, 3, 4, 8, 9]];
        assert!(objective(&specs, &clustered, &w) < objective(&specs, &mixed, &w));
    }

    #[test]
    fn greedy_close_to_clustered_objective() {
        let specs = cluster_specs();
        let w = FactorWeights::default();
        let groups = group_fragments(&specs, &GroupOptions::default());
        let best = objective(&specs, &vec![vec![0, 1, 2, 3, 4], vec![5, 6, 7, 8, 9]], &w);
        let got = objective(&specs, &groups, &w);
        assert!(got <= best * 1.05, "greedy {got} vs clustered {best}");
    }

    /// The seed's greedy, verbatim: per-candidate edge-list rebuild with
    /// the two-pass variance.  Reference for the rewrite's equivalence.
    fn group_fragments_reference(
        specs: &[FragmentSpec],
        opts: &GroupOptions,
    ) -> Vec<Vec<usize>> {
        let n = specs.len();
        if n == 0 {
            return Vec::new();
        }
        let gs = opts.group_size.max(1);
        let k = n.div_ceil(gs);
        if k <= 1 {
            return vec![(0..n).collect()];
        }
        let cap = n.div_ceil(k);
        let props: Vec<[f64; 3]> =
            specs.iter().map(FragmentSpec::property_vector).collect();
        let sc = scales(&props);
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seed_from_u64(opts.seed);
        rng.shuffle(&mut order);
        let mut groups: Vec<Vec<usize>> =
            order[..k].iter().map(|&i| vec![i]).collect();
        let var = |e: &[f64]| {
            if e.is_empty() {
                return 0.0;
            }
            let m = e.iter().sum::<f64>() / e.len() as f64;
            e.iter().map(|x| (x - m).powi(2)).sum::<f64>() / e.len() as f64
        };
        for &i in &order[k..] {
            let mut best: Option<(usize, f64)> = None;
            for (gk, g) in groups.iter().enumerate() {
                if g.len() >= cap {
                    continue;
                }
                let new_edges: Vec<f64> = g
                    .iter()
                    .map(|&j| {
                        similarity(&props[i], &props[j], &opts.weights, &sc)
                    })
                    .collect();
                let mut edges = Vec::new();
                for (ai, &a) in g.iter().enumerate() {
                    for &b in &g[ai + 1..] {
                        edges.push(similarity(
                            &props[a], &props[b], &opts.weights, &sc,
                        ));
                    }
                }
                let before = var(&edges);
                edges.extend_from_slice(&new_edges);
                let delta = var(&edges) - before
                    - new_edges.iter().sum::<f64>();
                if best.map_or(true, |(_, b)| delta < b) {
                    best = Some((gk, delta));
                }
            }
            let (gk, _) = best.expect("some group has room");
            groups[gk].push(i);
        }
        groups
    }

    #[test]
    fn rewrite_matches_seed_greedy_on_fixtures() {
        // identical groups on the well-separated fixture set
        let specs = cluster_specs();
        let opts = GroupOptions { group_size: 5, ..Default::default() };
        assert_eq!(
            group_fragments(&specs, &opts),
            group_fragments_reference(&specs, &opts)
        );
        // and the same objective (within FP noise of the running-moment
        // variance) on randomized sets at several sizes and seeds
        let w = FactorWeights::default();
        for seed in 0..10u64 {
            let mut rng = Rng::seed_from_u64(777 + seed);
            let n = 6 + rng.below(40);
            let specs: Vec<FragmentSpec> = (0..n)
                .map(|i| {
                    spec(
                        i as u32,
                        rng.below(16),
                        rng.range(30.0, 200.0),
                        rng.range(1.0, 90.0),
                    )
                })
                .collect();
            let opts = GroupOptions {
                group_size: 2 + rng.below(5),
                seed,
                ..Default::default()
            };
            let new = objective(&specs, &group_fragments(&specs, &opts), &w);
            let old = objective(
                &specs,
                &group_fragments_reference(&specs, &opts),
                &w,
            );
            assert!(
                (new - old).abs() <= 1e-6 * (1.0 + old.abs()),
                "seed {seed}: rewrite {new} vs reference {old}"
            );
        }
    }

    fn random_specs(n: usize, seed: u64) -> Vec<FragmentSpec> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                spec(
                    i as u32,
                    rng.below(16),
                    rng.range(30.0, 200.0),
                    rng.range(1.0, 90.0),
                )
            })
            .collect()
    }

    #[test]
    fn dense_and_lazy_tables_group_identically() {
        // dense_limit 0 forces the lazy path at any n; groups must not
        // depend on which lookup backs the greedy
        for seed in 0..5u64 {
            let specs = random_specs(60, 900 + seed);
            let dense = GroupOptions { seed, ..Default::default() };
            let lazy = GroupOptions { dense_limit: 0, ..dense };
            assert_eq!(
                group_fragments(&specs, &dense),
                group_fragments(&specs, &lazy),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn incremental_replays_unchanged_demands_byte_identically() {
        let specs = random_specs(50, 42);
        let opts = GroupOptions::default();
        let (cold, state) = group_fragments_incremental(&specs, &opts, None);
        assert!(!cold.fell_back);
        assert_eq!(cold.regrouped, 50);
        assert_eq!(cold.groups, group_fragments(&specs, &opts));
        let (warm, state2) =
            group_fragments_incremental(&specs, &opts, Some(&state));
        assert_eq!(warm.groups, cold.groups, "replay must be byte-identical");
        assert_eq!(warm.regrouped, 0);
        assert_eq!(warm.replayed, cold.groups.len());
        assert!(!warm.fell_back);
        assert_eq!(state2, state);
    }

    /// Satellite regression: the scratch greedy reshuffles everything
    /// when `n` changes; the incremental path must keep group churn
    /// bounded by 2x the perturbed-fragment count (each change touches
    /// at most its old group and its new group).
    #[test]
    fn incremental_bounds_group_churn_at_one_percent() {
        let mut specs = random_specs(200, 7);
        let opts = GroupOptions::default();
        let (_, state) = group_fragments_incremental(&specs, &opts, None);
        // perturb 1% = 2 fragments (budget moves, like a drifting SLO)
        for i in [30usize, 140] {
            specs[i].budget_ms += 1.0;
        }
        let (delta, state2) =
            group_fragments_incremental(&specs, &opts, Some(&state));
        assert!(!delta.fell_back, "1% churn must stay on the delta path");
        assert_eq!(delta.regrouped, 2);
        // groups that differ from the previous trigger, by member keys
        let key_sets = |st: &GroupState| -> Vec<Vec<Vec<ClientId>>> {
            st.groups
                .iter()
                .map(|g| {
                    let mut ks: Vec<Vec<ClientId>> =
                        g.iter().map(|m| m.key.clone()).collect();
                    ks.sort();
                    ks
                })
                .collect()
        };
        let before = key_sets(&state);
        let after = key_sets(&state2);
        let churned = after
            .iter()
            .filter(|g| !before.contains(g))
            .count()
            .max(before.iter().filter(|g| !after.contains(g)).count());
        assert!(churned <= 2 * 2, "churned {churned} groups for 2 changes");
        // partition stays valid
        let mut all: Vec<usize> = delta.groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<_>>());
        let cap = 200usize.div_ceil(200usize.div_ceil(opts.group_size));
        assert!(delta.groups.iter().all(|g| g.len() <= cap));
    }

    #[test]
    fn incremental_falls_back_on_heavy_churn() {
        let mut specs = random_specs(40, 11);
        let opts = GroupOptions::default();
        let (_, state) = group_fragments_incremental(&specs, &opts, None);
        for s in specs.iter_mut().take(30) {
            s.budget_ms += 5.0; // 75% of members move: over the threshold
        }
        let (delta, _) =
            group_fragments_incremental(&specs, &opts, Some(&state));
        assert!(delta.fell_back);
        assert_eq!(delta.regrouped, 40);
        assert_eq!(delta.groups, group_fragments(&specs, &opts));
    }

    #[test]
    fn incremental_objective_within_epsilon_of_scratch_when_audited() {
        // audit forced at every n: the returned grouping can never
        // drift past ε of the scratch oracle (by construction — the
        // audit falls back when it would)
        let opts =
            GroupOptions { audit_limit: usize::MAX, ..Default::default() };
        let w = opts.weights;
        for seed in 0..8u64 {
            let mut rng = Rng::seed_from_u64(3000 + seed);
            let mut specs = random_specs(60 + rng.below(60), 50 + seed);
            let (_, mut state) =
                group_fragments_incremental(&specs, &opts, None);
            for _step in 0..3 {
                let n = specs.len();
                for _ in 0..(n / 20).max(1) {
                    let i = rng.below(n);
                    specs[i].budget_ms += rng.range(-2.0, 2.0);
                    specs[i].rate_rps =
                        (specs[i].rate_rps + rng.range(-1.0, 1.0)).max(0.5);
                }
                let (delta, next) =
                    group_fragments_incremental(&specs, &opts, Some(&state));
                let inc = objective(&specs, &delta.groups, &w);
                let scr =
                    objective(&specs, &group_fragments(&specs, &opts), &w);
                assert!(
                    inc <= scr * (1.0 + opts.epsilon) + 1e-9,
                    "seed {seed}: incremental {inc} vs scratch {scr}"
                );
                state = next;
            }
        }
    }

    #[test]
    fn group_state_json_roundtrip_is_exact() {
        let specs = random_specs(30, 99);
        let opts = GroupOptions::default();
        let (_, state) = group_fragments_incremental(&specs, &opts, None);
        let doc = state.to_json().to_string();
        let back = GroupState::from_json(&Json::parse(&doc).unwrap()).unwrap();
        assert_eq!(back, state);
    }

    #[test]
    fn incremental_absorbs_arrivals_and_departures() {
        let mut specs = random_specs(80, 21);
        let opts = GroupOptions::default();
        let (_, state) = group_fragments_incremental(&specs, &opts, None);
        specs.remove(17); // one client departs...
        specs.push(spec(500, 4, 77.0, 12.0)); // ...and a new one arrives
        let (delta, state2) =
            group_fragments_incremental(&specs, &opts, Some(&state));
        assert!(!delta.fell_back);
        assert_eq!(delta.regrouped, 1, "only the arrival is regrouped");
        let mut all: Vec<usize> = delta.groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..80).collect::<Vec<_>>());
        assert_eq!(
            state2.groups.iter().map(Vec::len).sum::<usize>(),
            80,
            "state tracks the new population"
        );
    }
}
