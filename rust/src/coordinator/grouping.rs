//! §4.2 — DNN fragment grouping as balanced graph partitioning.
//!
//! Fragments are nodes of a complete graph; edge weights are the weighted
//! Euclidean distance between the property vectors `⟨p, t, q⟩`.  The
//! grouping problem is a variant of balanced graph partitioning: divide
//! the nodes into `K = ⌈n / group_size⌉` (nearly) equal, disjoint subsets
//! minimising Eq. (1) — the within-group edge-weight variance plus the
//! total cross-group edge weight.  We follow the Fennel-style greedy:
//! seed `K` groups with random fragments, then assign each remaining
//! fragment to the group with the least objective increase subject to
//! the balance cap.

use super::fragment::FragmentSpec;
use crate::util::Rng;

/// Factor weights for the distance on `⟨p, t, q⟩` (§5.6 explores these;
/// equal weights are within ~4% of optimal).
#[derive(Debug, Clone, Copy)]
pub struct FactorWeights {
    pub p: f64,
    pub t: f64,
    pub q: f64,
}

impl Default for FactorWeights {
    fn default() -> Self {
        Self { p: 1.0, t: 1.0, q: 1.0 }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct GroupOptions {
    /// Target group size (paper default 5; the knee of Fig 16a).
    pub group_size: usize,
    pub weights: FactorWeights,
    pub seed: u64,
}

impl Default for GroupOptions {
    fn default() -> Self {
        Self { group_size: 5, weights: FactorWeights::default(), seed: 0xF3A7 }
    }
}

/// Edge weight = *similarity* of two fragments: the paper assigns edge
/// weights "based on the similarity of the fragments ... using the
/// weighted Euclidean distance between the property vectors" — i.e. a
/// decreasing transform of the (normalised, weighted) distance, so that
/// minimising external edge weight keeps similar fragments together.
fn similarity(
    a: &[f64; 3],
    b: &[f64; 3],
    w: &FactorWeights,
    scale: &[f64; 3],
) -> f64 {
    let d = |i: usize, wi: f64| {
        let s = if scale[i] > 0.0 { scale[i] } else { 1.0 };
        wi * ((a[i] - b[i]) / s).powi(2)
    };
    let dist = (d(0, w.p) + d(1, w.t) + d(2, w.q)).sqrt();
    1.0 / (1.0 + dist)
}

/// Per-dimension ranges used for normalisation.
fn scales(props: &[[f64; 3]]) -> [f64; 3] {
    let mut s = [0.0f64; 3];
    for i in 0..3 {
        let min = props.iter().map(|p| p[i]).fold(f64::INFINITY, f64::min);
        let max = props.iter().map(|p| p[i]).fold(f64::NEG_INFINITY, f64::max);
        s[i] = max - min;
    }
    s
}

/// The Eq.-(1) objective of a complete grouping (used by tests and the
/// optimal-grouping baseline): Σ_k var(internal edges of k) + Σ external
/// edge weights.
pub fn objective(
    specs: &[FragmentSpec],
    groups: &[Vec<usize>],
    w: &FactorWeights,
) -> f64 {
    let props: Vec<[f64; 3]> =
        specs.iter().map(FragmentSpec::property_vector).collect();
    let sc = scales(&props);
    let mut in_group = vec![usize::MAX; specs.len()];
    for (k, g) in groups.iter().enumerate() {
        for &i in g {
            in_group[i] = k;
        }
    }
    let mut var_sum = 0.0;
    for g in groups {
        let mut edges = Vec::new();
        for (ai, &i) in g.iter().enumerate() {
            for &j in &g[ai + 1..] {
                edges.push(similarity(&props[i], &props[j], w, &sc));
            }
        }
        if !edges.is_empty() {
            let mean = edges.iter().sum::<f64>() / edges.len() as f64;
            var_sum += edges.iter().map(|e| (e - mean).powi(2)).sum::<f64>()
                / edges.len() as f64;
        }
    }
    let mut ext = 0.0;
    for i in 0..specs.len() {
        for j in i + 1..specs.len() {
            if in_group[i] != in_group[j] {
                ext += similarity(&props[i], &props[j], w, &sc);
            }
        }
    }
    var_sum + ext
}

/// Pairwise similarity lookup: a dense symmetric matrix when it fits in
/// a modest footprint (one similarity evaluation per pair for the whole
/// greedy run), falling back to on-the-fly evaluation at larger n (the
/// matrix would be O(n²) memory).  The seed re-evaluated every internal
/// edge of every candidate group per assignment — O(n · K · g²)
/// similarity calls; with this table plus the running-moment group stats
/// below, each candidate assignment costs O(group) lookups.
const DENSE_SIM_LIMIT: usize = 2048; // 2048² f64 = 32 MiB

enum SimTable<'a> {
    Dense { n: usize, m: Vec<f64> },
    Lazy { props: &'a [[f64; 3]], w: FactorWeights, sc: [f64; 3] },
}

impl<'a> SimTable<'a> {
    fn new(
        props: &'a [[f64; 3]],
        w: FactorWeights,
        sc: [f64; 3],
    ) -> SimTable<'a> {
        let n = props.len();
        if n > DENSE_SIM_LIMIT {
            return SimTable::Lazy { props, w, sc };
        }
        let mut m = vec![0.0; n * n];
        for i in 0..n {
            for j in i + 1..n {
                let s = similarity(&props[i], &props[j], &w, &sc);
                m[i * n + j] = s;
                m[j * n + i] = s;
            }
        }
        SimTable::Dense { n, m }
    }

    #[inline]
    fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            SimTable::Dense { n, m } => m[i * n + j],
            SimTable::Lazy { props, w, sc } => {
                similarity(&props[i], &props[j], w, sc)
            }
        }
    }
}

/// Running moments of a group's internal edge weights; variance in O(1)
/// from (Σe, Σe², count) instead of rebuilding the edge list.
#[derive(Clone, Copy, Default)]
struct GroupStats {
    sum: f64,
    sumsq: f64,
    count: usize,
}

impl GroupStats {
    #[inline]
    fn var(sum: f64, sumsq: f64, count: usize) -> f64 {
        if count == 0 {
            return 0.0;
        }
        let mean = sum / count as f64;
        // E[x²] − E[x]²; clamp the tiny negative values FP can produce
        (sumsq / count as f64 - mean * mean).max(0.0)
    }
}

/// Greedy balanced grouping (§4.2).  Returns index groups over `specs`.
/// All specs must belong to the same model (the scheduler splits by
/// model first — §6 "Heterogeneous models").
pub fn group_fragments(
    specs: &[FragmentSpec],
    opts: &GroupOptions,
) -> Vec<Vec<usize>> {
    let n = specs.len();
    if n == 0 {
        return Vec::new();
    }
    debug_assert!(
        specs.windows(2).all(|w| w[0].model == w[1].model),
        "grouping expects same-model fragments"
    );
    let gs = opts.group_size.max(1);
    let k = n.div_ceil(gs);
    if k <= 1 {
        return vec![(0..n).collect()];
    }
    let cap = n.div_ceil(k);

    let props: Vec<[f64; 3]> =
        specs.iter().map(FragmentSpec::property_vector).collect();
    let sc = scales(&props);
    let sim = SimTable::new(&props, opts.weights, sc);

    // (a) seed K groups with random fragments
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Rng::seed_from_u64(opts.seed);
    rng.shuffle(&mut order);
    let mut groups: Vec<Vec<usize>> =
        order[..k].iter().map(|&i| vec![i]).collect();
    let mut stats = vec![GroupStats::default(); k];

    // (b) assign the rest minimising the objective increase:
    //   Δ = Δvar(internal edges of k) − Σ edges(f ↔ members of k)
    // (the external-edge term decreases exactly by the edges absorbed).
    // Δvar comes from the running moments: O(group) edge lookups per
    // candidate, no edge-list rebuild.
    for &i in &order[k..] {
        // (group idx, delta, Σ new edges, Σ new edges²)
        let mut best: Option<(usize, f64, f64, f64)> = None;
        for (gk, g) in groups.iter().enumerate() {
            if g.len() >= cap {
                continue;
            }
            let mut esum = 0.0;
            let mut esumsq = 0.0;
            for &j in g {
                let e = sim.get(i, j);
                esum += e;
                esumsq += e * e;
            }
            let st = stats[gk];
            let var_before = GroupStats::var(st.sum, st.sumsq, st.count);
            let var_after = GroupStats::var(
                st.sum + esum,
                st.sumsq + esumsq,
                st.count + g.len(),
            );
            let delta = var_after - var_before - esum;
            if best.map_or(true, |(_, b, _, _)| delta < b) {
                best = Some((gk, delta, esum, esumsq));
            }
        }
        let (gk, _, esum, esumsq) =
            best.expect("cap * k >= n so some group has room");
        stats[gk].sum += esum;
        stats[gk].sumsq += esumsq;
        stats[gk].count += groups[gk].len();
        groups[gk].push(i);
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fragment::ClientId;

    fn spec(i: u32, p: usize, t: f64, q: f64) -> FragmentSpec {
        FragmentSpec::single(ClientId(i), 0, p, t, q)
    }

    fn cluster_specs() -> Vec<FragmentSpec> {
        // two obvious clusters: (p=2, t≈60) and (p=8, t≈120)
        let mut v = Vec::new();
        for i in 0..5 {
            v.push(spec(i, 2, 60.0 + i as f64, 30.0));
        }
        for i in 5..10 {
            v.push(spec(i, 8, 120.0 + i as f64, 30.0));
        }
        v
    }

    #[test]
    fn groups_are_balanced_disjoint_cover() {
        let specs = cluster_specs();
        let groups =
            group_fragments(&specs, &GroupOptions { group_size: 5, ..Default::default() });
        assert_eq!(groups.len(), 2);
        let mut all: Vec<usize> = groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        for g in &groups {
            assert!(g.len() <= 5);
        }
    }

    #[test]
    fn similar_fragments_group_together() {
        let specs = cluster_specs();
        let groups =
            group_fragments(&specs, &GroupOptions { group_size: 5, ..Default::default() });
        for g in &groups {
            let ps: Vec<usize> = g.iter().map(|&i| specs[i].p).collect();
            assert!(
                ps.iter().all(|&p| p == ps[0]),
                "mixed cluster in group: {ps:?}"
            );
        }
    }

    #[test]
    fn single_group_when_few_fragments() {
        let specs = cluster_specs()[..4].to_vec();
        let groups = group_fragments(&specs, &GroupOptions::default());
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].len(), 4);
    }

    #[test]
    fn empty_input() {
        assert!(group_fragments(&[], &GroupOptions::default()).is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let specs = cluster_specs();
        let a = group_fragments(&specs, &GroupOptions::default());
        let b = group_fragments(&specs, &GroupOptions::default());
        assert_eq!(a, b);
    }

    #[test]
    fn objective_prefers_clustered_grouping() {
        let specs = cluster_specs();
        let w = FactorWeights::default();
        let clustered = vec![vec![0, 1, 2, 3, 4], vec![5, 6, 7, 8, 9]];
        let mixed = vec![vec![0, 1, 5, 6, 7], vec![2, 3, 4, 8, 9]];
        assert!(objective(&specs, &clustered, &w) < objective(&specs, &mixed, &w));
    }

    #[test]
    fn greedy_close_to_clustered_objective() {
        let specs = cluster_specs();
        let w = FactorWeights::default();
        let groups = group_fragments(&specs, &GroupOptions::default());
        let best = objective(&specs, &vec![vec![0, 1, 2, 3, 4], vec![5, 6, 7, 8, 9]], &w);
        let got = objective(&specs, &groups, &w);
        assert!(got <= best * 1.05, "greedy {got} vs clustered {best}");
    }

    /// The seed's greedy, verbatim: per-candidate edge-list rebuild with
    /// the two-pass variance.  Reference for the rewrite's equivalence.
    fn group_fragments_reference(
        specs: &[FragmentSpec],
        opts: &GroupOptions,
    ) -> Vec<Vec<usize>> {
        let n = specs.len();
        if n == 0 {
            return Vec::new();
        }
        let gs = opts.group_size.max(1);
        let k = n.div_ceil(gs);
        if k <= 1 {
            return vec![(0..n).collect()];
        }
        let cap = n.div_ceil(k);
        let props: Vec<[f64; 3]> =
            specs.iter().map(FragmentSpec::property_vector).collect();
        let sc = scales(&props);
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::seed_from_u64(opts.seed);
        rng.shuffle(&mut order);
        let mut groups: Vec<Vec<usize>> =
            order[..k].iter().map(|&i| vec![i]).collect();
        let var = |e: &[f64]| {
            if e.is_empty() {
                return 0.0;
            }
            let m = e.iter().sum::<f64>() / e.len() as f64;
            e.iter().map(|x| (x - m).powi(2)).sum::<f64>() / e.len() as f64
        };
        for &i in &order[k..] {
            let mut best: Option<(usize, f64)> = None;
            for (gk, g) in groups.iter().enumerate() {
                if g.len() >= cap {
                    continue;
                }
                let new_edges: Vec<f64> = g
                    .iter()
                    .map(|&j| {
                        similarity(&props[i], &props[j], &opts.weights, &sc)
                    })
                    .collect();
                let mut edges = Vec::new();
                for (ai, &a) in g.iter().enumerate() {
                    for &b in &g[ai + 1..] {
                        edges.push(similarity(
                            &props[a], &props[b], &opts.weights, &sc,
                        ));
                    }
                }
                let before = var(&edges);
                edges.extend_from_slice(&new_edges);
                let delta = var(&edges) - before
                    - new_edges.iter().sum::<f64>();
                if best.map_or(true, |(_, b)| delta < b) {
                    best = Some((gk, delta));
                }
            }
            let (gk, _) = best.expect("some group has room");
            groups[gk].push(i);
        }
        groups
    }

    #[test]
    fn rewrite_matches_seed_greedy_on_fixtures() {
        // identical groups on the well-separated fixture set
        let specs = cluster_specs();
        let opts = GroupOptions { group_size: 5, ..Default::default() };
        assert_eq!(
            group_fragments(&specs, &opts),
            group_fragments_reference(&specs, &opts)
        );
        // and the same objective (within FP noise of the running-moment
        // variance) on randomized sets at several sizes and seeds
        let w = FactorWeights::default();
        for seed in 0..10u64 {
            let mut rng = Rng::seed_from_u64(777 + seed);
            let n = 6 + rng.below(40);
            let specs: Vec<FragmentSpec> = (0..n)
                .map(|i| {
                    spec(
                        i as u32,
                        rng.below(16),
                        rng.range(30.0, 200.0),
                        rng.range(1.0, 90.0),
                    )
                })
                .collect();
            let opts = GroupOptions {
                group_size: 2 + rng.below(5),
                seed,
                ..Default::default()
            };
            let new = objective(&specs, &group_fragments(&specs, &opts), &w);
            let old = objective(
                &specs,
                &group_fragments_reference(&specs, &opts),
                &w,
            );
            assert!(
                (new - old).abs() <= 1e-6 * (1.0 + old.abs()),
                "seed {seed}: rewrite {new} vs reference {old}"
            );
        }
    }
}
