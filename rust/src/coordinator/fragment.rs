//! Fragment specifications — the scheduler's unit of work.
//!
//! A `FragmentSpec` describes one server-side DNN fragment demand: the
//! model, the partition point `p` (the fragment is layers `p+1..=L`),
//! the server-side time budget `t` and the aggregate request rate `q` —
//! the property vector `⟨p, t, q⟩` of §4.2.  After merging (§4.1) one
//! spec may aggregate several clients.

use crate::hybrid::DeviceKind;

#[derive(Debug, Clone, PartialEq)]
pub struct FragmentSpec {
    /// Model index into `Config::models`.
    pub model: usize,
    /// Partition point: server executes layers `p+1 ..= layers`.
    pub p: usize,
    /// Server-side time budget (ms): SLO − mobile − transfer.
    pub budget_ms: f64,
    /// Aggregate request rate (RPS) across the merged clients.
    pub rate_rps: f64,
    /// Client ids merged into this spec (singleton before merging).
    pub clients: Vec<ClientId>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClientId(pub u32);

impl FragmentSpec {
    pub fn single(
        client: ClientId,
        model: usize,
        p: usize,
        budget_ms: f64,
        rate_rps: f64,
    ) -> Self {
        Self { model, p, budget_ms, rate_rps, clients: vec![client] }
    }

    /// Uniformity for merging (§4.1): same partition point and (within
    /// `tol_ms`) the same time budget.
    pub fn uniform_with(&self, other: &Self, tol_ms: f64) -> bool {
        self.model == other.model
            && self.p == other.p
            && (self.budget_ms - other.budget_ms).abs() <= tol_ms
    }

    /// Merge `other` into `self`: rates add, the budget tightens to the
    /// smaller one (all merged requests must meet the tightest budget).
    pub fn merge(&mut self, other: &Self) {
        assert_eq!(self.model, other.model);
        assert_eq!(self.p, other.p);
        self.rate_rps += other.rate_rps;
        self.budget_ms = self.budget_ms.min(other.budget_ms);
        self.clients.extend(other.clients.iter().copied());
    }

    /// Property vector `⟨p, t, q⟩` used for grouping similarity (§4.2).
    pub fn property_vector(&self) -> [f64; 3] {
        [self.p as f64, self.budget_ms, self.rate_rps]
    }

    /// JSON form for replan-context persistence.  Exact: floats
    /// round-trip bit-identically through the shortest-repr printer, so
    /// a reloaded spec still satisfies the caches' equality checks.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let mut o = std::collections::BTreeMap::new();
        o.insert("model".into(), Json::Num(self.model as f64));
        o.insert("p".into(), Json::Num(self.p as f64));
        o.insert("budget_ms".into(), Json::Num(self.budget_ms));
        o.insert("rate_rps".into(), Json::Num(self.rate_rps));
        o.insert(
            "clients".into(),
            Json::Arr(
                self.clients
                    .iter()
                    .map(|c| Json::Num(c.0 as f64))
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    /// Inverse of [`Self::to_json`].
    pub fn from_json(v: &crate::util::Json) -> anyhow::Result<FragmentSpec> {
        Ok(FragmentSpec {
            model: v.get("model")?.as_usize()?,
            p: v.get("p")?.as_usize()?,
            budget_ms: v.get("budget_ms")?.as_f64()?,
            rate_rps: v.get("rate_rps")?.as_f64()?,
            clients: v
                .get("clients")?
                .as_arr()?
                .iter()
                .map(|c| Ok(ClientId(c.as_usize()? as u32)))
                .collect::<anyhow::Result<_>>()?,
        })
    }
}

/// A client's identity + current fragment demand, as tracked online.
#[derive(Debug, Clone)]
pub struct ClientDemand {
    pub id: ClientId,
    pub device: DeviceKind,
    pub spec: FragmentSpec,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(p: usize, t: f64, q: f64) -> FragmentSpec {
        FragmentSpec::single(ClientId(0), 0, p, t, q)
    }

    #[test]
    fn uniformity_requires_same_point_and_close_budget() {
        let a = spec(3, 50.0, 30.0);
        assert!(a.uniform_with(&spec(3, 50.4, 10.0), 0.5));
        assert!(!a.uniform_with(&spec(4, 50.0, 30.0), 0.5));
        assert!(!a.uniform_with(&spec(3, 52.0, 30.0), 0.5));
        let mut b = spec(3, 50.0, 30.0);
        b.model = 1;
        assert!(!a.uniform_with(&b, 0.5));
    }

    #[test]
    fn merge_adds_rates_and_tightens_budget() {
        let mut a = spec(3, 50.0, 30.0);
        let mut b = spec(3, 45.0, 30.0);
        b.clients = vec![ClientId(1)];
        a.merge(&b);
        assert_eq!(a.rate_rps, 60.0);
        assert_eq!(a.budget_ms, 45.0);
        assert_eq!(a.clients, vec![ClientId(0), ClientId(1)]);
    }

    #[test]
    fn property_vector_order() {
        assert_eq!(spec(3, 50.0, 30.0).property_vector(), [3.0, 50.0, 30.0]);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut s = spec(3, 80.6, 31.25);
        s.clients = vec![ClientId(4), ClientId(9)];
        let doc = s.to_json().to_string();
        let back = FragmentSpec::from_json(
            &crate::util::Json::parse(&doc).unwrap(),
        )
        .unwrap();
        assert_eq!(back, s);
    }
}
