//! The Graft scheduler (paper §3/§4): merge → group → re-partition →
//! place.
//!
//! Takes the live set of fragment demands (one per mobile client), runs
//! the three §4 steps and emits an [`ExecutionPlan`].  Groups are
//! re-aligned in parallel on a configurable thread pool (the paper's
//! "process pool", §5.9/Fig 19b).  The scheduler is cheap enough to be
//! re-invoked on every partition-point change (trigger-based
//! re-planning), and the whole pipeline is delta-aware across triggers.
//! Merging, re-partitioning and placement reuse are exact (unchanged
//! inputs replay byte-identical outputs, property-tested); grouping
//! reuse is heuristic with an audited quality bound (below):
//!
//! * **merging** re-runs only the uniform classes whose membership
//!   changed, splicing cached outputs for the clean ones
//!   ([`crate::coordinator::merging::merge_fragments_incremental`]);
//! * **grouping** diffs each model's merged fragments against the
//!   previous trigger by member identity: unchanged demands replay the
//!   previous groups byte-identically, and only new/changed fragments
//!   go through the greedy — falling back to the from-scratch greedy
//!   on heavy churn or Eq.-(1) objective drift past ε
//!   ([`crate::coordinator::grouping::group_fragments_incremental`]);
//!   stable groups keep `group_signature`s stable, so the exact caches
//!   below stop churning under small perturbations;
//! * **re-partitioning** replays cached per-group plans for groups
//!   whose exact fragment signature is unchanged, and warm-starts the
//!   suffix DP of the groups that did move from the previous trigger's
//!   chosen re-partition points
//!   ([`crate::coordinator::repartition::realign_group_warm`] — hints
//!   are advisory, keyed by the perturbation-stable
//!   [`crate::coordinator::reuse::warm_signature`]);
//! * the **d_shared grid** search inside each re-alignment is adaptive
//!   (coarse sweep + bound-screened refinement at the same effective
//!   resolution).
//!
//! The cross-trigger state (merge-class cache, DP choice tables) lives
//! in a [`ReplanContext`] next to the exact group-plan cache;
//! [`ScheduleStats`] reports per-phase reuse counters so replan cost is
//! observable (`graft plan`, `graft bench-scheduler`'s replan
//! scenario).
//!
//! Placement (§5.1/§5.3) is part of planning, not an afterthought: the
//! assembled plan is packed onto GPUs first-fit-decreasing under the
//! share + memory caps ([`crate::coordinator::placement`]) and the
//! winning per-instance assignments are stamped into the plan.  When
//! packing fails (an instance no single GPU can host) or fragments
//! badly (placed GPUs far above the share lower bound), the scheduler
//! *re-enters* re-partitioning with tightened per-instance ceilings —
//! splitting fat instances into placeable ones — and keeps a tightened
//! plan only when it strictly reduces the GPU count (or turns an
//! unpackable plan packable), so the integrated planner never does
//! worse than post-hoc FFD packing of the same demand.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::Instant;

use super::fragment::FragmentSpec;
use super::grouping::{
    group_fragments, group_fragments_incremental, GroupOptions, GroupState,
};
use super::merging::{
    merge_fragments, merge_fragments_incremental, MergeCache, MergeOptions,
};
use super::placement::{place, stamp, Placement, PlacementOptions};
use super::plan::ExecutionPlan;
use super::repartition::{
    realign_group_warm, RepartitionOptions, RepartitionTelemetry,
};
use super::reuse::{group_signature, repartition_signature, warm_signature};
use crate::profiler::CostModel;
use crate::util::lock::lock_recover;
use crate::util::parallel_map;

#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    pub merge: MergeOptions,
    pub group: GroupOptions,
    pub repartition: RepartitionOptions,
    /// Planner-integrated GPU placement + feedback loop.
    pub placement: PlacementOptions,
    /// Thread-pool size for parallel per-group re-alignment (Fig 19b).
    pub pool_size: usize,
    /// Reuse state across triggers: per-group plans (exact — cache hits
    /// are verified by full spec equality), the dirty-class merge cache,
    /// DP warm hints, and — when `group.incremental` is also set — the
    /// delta-aware grouping state.  With grouping reuse off the whole
    /// incremental pipeline is exact (plans identical to from-scratch
    /// planning, property-tested); with it on, unchanged demands still
    /// replay byte-identical plans while perturbed triggers trade exact
    /// group identity for an ε-audited objective bound.
    pub incremental: bool,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        Self {
            merge: MergeOptions::default(),
            group: GroupOptions::default(),
            repartition: RepartitionOptions::default(),
            placement: PlacementOptions::default(),
            pool_size: 2, // paper default (§5.9)
            incremental: true,
        }
    }
}

/// Timing / size statistics of one scheduling run (Figs 14, 19).
#[derive(Debug, Clone, Default)]
pub struct ScheduleStats {
    pub n_input: usize,
    pub n_after_merge: usize,
    pub n_groups: usize,
    /// Groups served from the incremental cache this trigger.
    pub n_groups_reused: usize,
    pub merge_ms: f64,
    pub group_ms: f64,
    pub repartition_ms: f64,
    pub placement_ms: f64,
    /// Tightening rounds the placement feedback loop evaluated (0 =
    /// the first placement was accepted as-is).
    pub placement_rounds: usize,
    /// GPUs of the stamped placement (0 when placement is disabled or
    /// the plan is empty).
    pub gpus: usize,
    /// Unused share fraction across those GPUs.
    pub fragmentation: f64,
    /// Placement (and every tightening round) failed — reachable only
    /// under a hard `max_gpus` cluster cap or with `max_rounds = 0`;
    /// the returned plan is unstamped and the executor should expect
    /// to shed load.
    pub placement_failed: bool,
    /// Uniform merge classes the demand set segmented into (incremental
    /// mode only; 0 when `incremental` is off).
    pub merge_classes: usize,
    /// Classes whose membership changed since the previous trigger and
    /// were re-merged (the rest spliced cached results).
    pub classes_remerged: usize,
    /// Groups replayed byte-identically from the previous trigger by
    /// the delta-aware grouping (incremental grouping only; 0 when off).
    pub groups_replayed: usize,
    /// Fragments the delta-aware grouping actually pushed through the
    /// greedy this trigger (new, moved, or — on fallback — the whole
    /// model slice).  0 on an unchanged trigger.
    pub fragments_regrouped: usize,
    /// Model slices where the delta path fell back to the from-scratch
    /// greedy (churn over threshold or ε-audit breach).
    pub group_fallbacks: usize,
    /// Suffix-DP states whose winning choice was seeded from the
    /// previous trigger's re-partition points (warm-started DP).
    pub dp_warm_hits: u64,
    /// d_shared grid points whose member sweep ran, across every
    /// re-aligned group (including placement feedback rounds).
    pub grid_points_evaluated: u64,
    /// Grid points the adaptive search dismissed after the shared-stage
    /// allocation alone.
    pub grid_points_pruned: u64,
    pub total_ms: f64,
}

/// One cached group plan: the exact specs (so signature-hash collisions
/// can never surface a wrong plan), the plan, and the last trigger
/// generation that touched it.
struct CachedGroupPlan {
    specs: Vec<FragmentSpec>,
    plan: ExecutionPlan,
    generation: u64,
}

/// Generational group-plan cache.  Each `plan()` call bumps the
/// generation and refreshes the entries it hits; when the entry count
/// exceeds the capacity, eviction drops only entries *not* touched
/// within the last trigger — the live working set always survives, so
/// steady-state replay never falls off a clear-everything cliff.
struct GroupCache {
    map: HashMap<u64, Vec<CachedGroupPlan>>,
    entries: usize,
    generation: u64,
}

const GROUP_CACHE_CAPACITY: usize = 1 << 16;
const DP_HINT_CAPACITY: usize = 1 << 16;

/// The previous trigger's winning re-partition points for one
/// (approximate) group.
struct DpHintEntry {
    points: Vec<usize>,
    generation: u64,
}

/// Cross-trigger replan state: the dirty-class merge cache and the DP
/// choice tables, keyed by the perturbation-stable
/// [`warm_signature`] (model + client ids — budgets, rates and split
/// points excluded, so a group whose members merely moved still finds
/// its previous choices).  Hints only seed the DP incumbent, so stale
/// or colliding entries can never change a plan — unlike the exact
/// group cache, no equality verification is needed.
struct ReplanContext {
    merge: MergeCache,
    dp: HashMap<u64, DpHintEntry>,
    /// Previous trigger's grouping state, keyed by model index (one
    /// entry per model ever planned — bounded by the model count, so no
    /// generational eviction is needed).
    groups: HashMap<usize, GroupState>,
    generation: u64,
}

pub struct Scheduler {
    cm: CostModel,
    pub opts: SchedulerOptions,
    group_cache: Mutex<GroupCache>,
    replan: Mutex<ReplanContext>,
}

impl Scheduler {
    pub fn new(cm: CostModel, opts: SchedulerOptions) -> Self {
        Self {
            cm,
            opts,
            group_cache: Mutex::new(GroupCache {
                map: HashMap::new(),
                entries: 0,
                generation: 0,
            }),
            replan: Mutex::new(ReplanContext {
                merge: MergeCache::default(),
                dp: HashMap::new(),
                groups: HashMap::new(),
                generation: 0,
            }),
        }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cm
    }

    /// Persist the cross-trigger replan context (merge-class cache, DP
    /// choice tables, per-model grouping state) as JSON, so a restarted
    /// scheduler's first live replan is still warm.  The exact
    /// group-plan cache is *not* persisted: it stores whole plans
    /// (orders of magnitude bigger) and a cold group recompute is
    /// precisely what the warm DP hints accelerate.  Written atomically
    /// (tmp + rename), so a crash mid-save never leaves a truncated
    /// context.
    pub fn save_replan_context(
        &self,
        path: &std::path::Path,
    ) -> anyhow::Result<()> {
        use crate::util::Json;
        let ctx = lock_recover(&self.replan);
        let mut dp = Vec::new();
        for (sig, e) in &ctx.dp {
            let mut o = std::collections::BTreeMap::new();
            o.insert("sig".into(), Json::Str(format!("{sig:016x}")));
            o.insert(
                "points".into(),
                Json::Arr(
                    e.points.iter().map(|&p| Json::Num(p as f64)).collect(),
                ),
            );
            dp.push(Json::Obj(o));
        }
        // models sorted so the file is deterministic for a given state
        let mut models: Vec<usize> = ctx.groups.keys().copied().collect();
        models.sort_unstable();
        let groups: Vec<Json> = models
            .iter()
            .map(|&m| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("model".into(), Json::Num(m as f64));
                o.insert("state".into(), ctx.groups[&m].to_json());
                Json::Obj(o)
            })
            .collect();
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("context".into(), Json::Str("replan".into()));
        doc.insert("schema_version".into(), Json::Num(2.0));
        doc.insert("merge".into(), ctx.merge.to_json());
        doc.insert("dp".into(), Json::Arr(dp));
        doc.insert("groups".into(), Json::Arr(groups));
        drop(ctx);
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, format!("{}\n", Json::Obj(doc)))?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Reload a context saved by [`Self::save_replan_context`] into
    /// this scheduler, replacing its current replan state.  Returns
    /// `(merge classes, dp hints)` loaded.  Accepts schema v1 (pre
    /// incremental grouping — no `groups` section) and v2.  Safe
    /// against stale or mismatched files: merge entries are verified by
    /// full spec equality on every lookup, DP hints are advisory, and
    /// grouping state is diffed by member identity (a stale state just
    /// shows up as churn), so the worst a wrong context can do is miss.
    pub fn load_replan_context(
        &self,
        path: &std::path::Path,
    ) -> anyhow::Result<(usize, usize)> {
        use crate::util::Json;
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(text.trim())?;
        if doc.get("context")?.as_str()? != "replan" {
            anyhow::bail!("not a replan context file");
        }
        let version = doc.get("schema_version")?.as_usize()?;
        if !(1..=2).contains(&version) {
            anyhow::bail!("unsupported replan-context schema v{version}");
        }
        let merge = MergeCache::from_json(doc.get("merge")?)?;
        let mut dp = HashMap::new();
        for e in doc.get("dp")?.as_arr()? {
            let sig = u64::from_str_radix(e.get("sig")?.as_str()?, 16)?;
            let points = e.get("points")?.as_usize_vec()?;
            dp.insert(sig, DpHintEntry { points, generation: 0 });
        }
        let mut groups = HashMap::new();
        if version >= 2 {
            for e in doc.get("groups")?.as_arr()? {
                groups.insert(
                    e.get("model")?.as_usize()?,
                    GroupState::from_json(e.get("state")?)?,
                );
            }
        }
        let counts = (merge.len(), dp.len());
        let mut ctx = lock_recover(&self.replan);
        ctx.merge = merge;
        ctx.dp = dp;
        ctx.groups = groups;
        ctx.generation = 0;
        Ok(counts)
    }

    /// Drop all incrementally cached replan state — group plans, merge
    /// classes and DP choice tables (e.g. after mutating `opts` —
    /// signatures also cover the options, so this is belt-and-braces,
    /// not correctness).
    pub fn clear_plan_cache(&self) {
        let mut cache = lock_recover(&self.group_cache);
        cache.map.clear();
        cache.entries = 0;
        drop(cache);
        let mut ctx = lock_recover(&self.replan);
        ctx.merge.clear();
        ctx.dp.clear();
        ctx.groups.clear();
    }

    /// Produce the execution plan for the given demands.
    pub fn plan(&self, demands: &[FragmentSpec]) -> (ExecutionPlan, ScheduleStats) {
        let t0 = Instant::now();
        let mut stats = ScheduleStats {
            n_input: demands.len(),
            ..Default::default()
        };
        if self.opts.incremental {
            self.begin_trigger();
        }

        // Step 1 — merging (§4.1), per model implicitly via uniformity;
        // incremental mode re-merges only the dirty uniform classes.
        let t = Instant::now();
        let merged = if self.opts.incremental {
            let mut ctx = lock_recover(&self.replan);
            let out = merge_fragments_incremental(
                &self.cm,
                demands,
                &self.opts.merge,
                &mut ctx.merge,
            );
            stats.merge_classes = out.classes;
            stats.classes_remerged = out.classes_remerged;
            out.merged
        } else {
            merge_fragments(&self.cm, demands, &self.opts.merge)
        };
        stats.merge_ms = t.elapsed().as_secs_f64() * 1e3;
        stats.n_after_merge = merged.len();

        // Step 2 — grouping (§4.2), per model (§6: heterogeneous models
        // are separated by type before grouping).  `merged` is sorted by
        // model, so each model is a contiguous slice — grouped in place,
        // then the specs are *moved* into their groups.  (The seed built
        // a cloned per-model Vec via filter().cloned() for every model,
        // then cloned again per group member.)
        let t = Instant::now();
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        let mut start = 0;
        for i in 1..=merged.len() {
            if i == merged.len() || merged[i].model != merged[start].model {
                ranges.push((start, i));
                start = i;
            }
        }
        let mut idx_groups: Vec<Vec<usize>> = Vec::new();
        if self.opts.incremental && self.opts.group.incremental {
            // delta-aware grouping: diff each model slice against the
            // previous trigger's persisted state
            let mut ctx = lock_recover(&self.replan);
            for &(a, b) in &ranges {
                let model = merged[a].model;
                let (delta, state) = group_fragments_incremental(
                    &merged[a..b],
                    &self.opts.group,
                    ctx.groups.get(&model),
                );
                stats.groups_replayed += delta.replayed;
                stats.fragments_regrouped += delta.regrouped;
                if delta.fell_back {
                    stats.group_fallbacks += 1;
                }
                for ig in delta.groups {
                    idx_groups
                        .push(ig.into_iter().map(|i| a + i).collect());
                }
                ctx.groups.insert(model, state);
            }
        } else {
            for &(a, b) in &ranges {
                for idx_group in
                    group_fragments(&merged[a..b], &self.opts.group)
                {
                    idx_groups
                        .push(idx_group.into_iter().map(|i| a + i).collect());
                }
            }
        }
        let mut slots: Vec<Option<FragmentSpec>> =
            merged.into_iter().map(Some).collect();
        let groups: Vec<Vec<FragmentSpec>> = idx_groups
            .into_iter()
            .map(|ig| {
                ig.into_iter()
                    .map(|i| {
                        slots[i].take().expect("fragment in exactly one group")
                    })
                    .collect()
            })
            .collect();
        stats.group_ms = t.elapsed().as_secs_f64() * 1e3;
        stats.n_groups = groups.len();

        // Step 3 — re-partitioning (§4.3): unchanged groups replay their
        // cached sets, the rest re-align in parallel with the previous
        // trigger's DP choices as warm hints.
        let t = Instant::now();
        let telemetry = RepartitionTelemetry::default();
        let (mut plan, reused_count) =
            self.repartition_pass(&groups, &self.opts.repartition, &telemetry);
        stats.n_groups_reused = reused_count;
        stats.repartition_ms = t.elapsed().as_secs_f64() * 1e3;

        // Step 4 — placement (§5.1/§5.3): pack onto GPUs, and feed
        // fragmentation/unplaceability back into re-partitioning.
        if self.opts.placement.enabled {
            let t = Instant::now();
            self.place_with_feedback(&mut plan, &groups, &mut stats, &telemetry);
            stats.placement_ms = t.elapsed().as_secs_f64() * 1e3;
        }

        stats.dp_warm_hits = telemetry.dp_warm_hits.load(Ordering::Relaxed);
        stats.grid_points_evaluated =
            telemetry.grid_points_evaluated.load(Ordering::Relaxed);
        stats.grid_points_pruned =
            telemetry.grid_points_pruned.load(Ordering::Relaxed);
        stats.total_ms = t0.elapsed().as_secs_f64() * 1e3;
        (plan, stats)
    }

    /// Open a new trigger generation on every cross-trigger cache: bump
    /// the generations once and evict stale entries when over capacity.
    /// Called once per `plan()` — the placement feedback rounds within a
    /// trigger share the generation, so the "previous trigger's working
    /// set survives eviction" invariant holds regardless of how many
    /// re-partitioning passes a trigger runs.  (The merge cache bumps
    /// its own generation inside `merge_fragments_incremental`.)
    fn begin_trigger(&self) {
        let mut cache = lock_recover(&self.group_cache);
        cache.generation += 1;
        let gen = cache.generation;
        if cache.entries > GROUP_CACHE_CAPACITY {
            // evict everything not touched by the previous trigger;
            // the live working set always survives
            for bucket in cache.map.values_mut() {
                bucket.retain(|e| e.generation + 1 >= gen);
            }
            cache.map.retain(|_, b| !b.is_empty());
            let remaining: usize = cache.map.values().map(Vec::len).sum();
            cache.entries = remaining;
        }
        drop(cache);
        let mut ctx = lock_recover(&self.replan);
        ctx.generation += 1;
        let gen = ctx.generation;
        if ctx.dp.len() > DP_HINT_CAPACITY {
            ctx.dp.retain(|_, e| e.generation + 1 >= gen);
        }
    }

    /// One re-partitioning pass over the grouped demands with the given
    /// options (the feedback loop calls this again with tightened
    /// constraints — each options signature keeps its own cache
    /// entries).  Returns the assembled plan and the reused-group count.
    fn repartition_pass(
        &self,
        groups: &[Vec<FragmentSpec>],
        rep_opts: &RepartitionOptions,
        telemetry: &RepartitionTelemetry,
    ) -> (ExecutionPlan, usize) {
        let opts_sig = repartition_signature(rep_opts);
        let mut reused: Vec<Option<ExecutionPlan>> = vec![None; groups.len()];
        let mut hints: Vec<Option<Vec<usize>>> = vec![None; groups.len()];
        // one warm-signature hash per group, shared by the hint lookup
        // and the end-of-pass DP table refresh
        let mut warm_sigs: Vec<u64> = Vec::new();
        if self.opts.incremental {
            warm_sigs = groups
                .iter()
                .map(|g| warm_signature(g, opts_sig))
                .collect();
            {
                let mut cache = lock_recover(&self.group_cache);
                let gen = cache.generation;
                for (gi, g) in groups.iter().enumerate() {
                    if let Some(bucket) =
                        cache.map.get_mut(&group_signature(g, opts_sig))
                    {
                        if let Some(e) =
                            bucket.iter_mut().find(|e| &e.specs == g)
                        {
                            e.generation = gen;
                            reused[gi] = Some(e.plan.clone());
                        }
                    }
                }
            }
            // warm DP hints for the groups that must recompute
            let ctx = lock_recover(&self.replan);
            for gi in 0..groups.len() {
                if reused[gi].is_none() {
                    if let Some(e) = ctx.dp.get(&warm_sigs[gi]) {
                        hints[gi] = Some(e.points.clone());
                    }
                }
            }
        }
        let todo: Vec<(usize, &Vec<FragmentSpec>)> = groups
            .iter()
            .enumerate()
            .filter(|(gi, _)| reused[*gi].is_none())
            .collect();
        let computed: Vec<ExecutionPlan> =
            parallel_map(&todo, self.opts.pool_size, |(gi, g)| {
                realign_group_warm(
                    &self.cm,
                    g.as_slice(),
                    rep_opts,
                    hints[*gi].as_deref(),
                    Some(telemetry),
                )
            });
        let mut computed = computed.into_iter();
        let mut plan = ExecutionPlan::default();
        let mut n_reused = 0;
        // fresh plans enter the exact group cache; every group (fresh
        // or replayed) refreshes its DP choice table for the next
        // trigger — both inserted in one batch under each lock
        let mut fresh: Vec<(usize, ExecutionPlan)> = Vec::new();
        let mut dp_updates: Vec<(u64, Vec<usize>)> = Vec::new();
        for (gi, cached) in reused.into_iter().enumerate() {
            let p = match cached {
                Some(p) => {
                    n_reused += 1;
                    p
                }
                None => {
                    let p = computed
                        .next()
                        .expect("one computed plan per uncached group");
                    if self.opts.incremental {
                        fresh.push((gi, p.clone()));
                    }
                    p
                }
            };
            if self.opts.incremental {
                dp_updates.push((warm_sigs[gi], p.realign_points()));
            }
            plan.merge_with(p);
        }
        if self.opts.incremental {
            if !fresh.is_empty() {
                let mut cache = lock_recover(&self.group_cache);
                let generation = cache.generation;
                for (gi, p) in fresh {
                    cache
                        .map
                        .entry(group_signature(&groups[gi], opts_sig))
                        .or_default()
                        .push(CachedGroupPlan {
                            specs: groups[gi].clone(),
                            plan: p,
                            generation,
                        });
                    cache.entries += 1;
                }
            }
            let mut ctx = lock_recover(&self.replan);
            let generation = ctx.generation;
            for (sig, points) in dp_updates {
                // latest trigger wins: hints are advisory, one entry
                // per warm key is enough
                ctx.dp.insert(sig, DpHintEntry { points, generation });
            }
        }
        (plan, n_reused)
    }

    /// The placement feedback loop.  Round 0 places the plan as
    /// emitted; when that is unplaceable or fragments beyond the
    /// configured threshold, up to `max_rounds` re-partitioning passes
    /// run with progressively tighter per-instance ceilings
    /// (`max_share` halved/thirded, per-instance memory capped at one
    /// GPU).  A tightened plan is kept only when it strictly lowers
    /// the GPU count without shedding clients, or turns an unpackable
    /// plan packable — so the final plan never packs onto more GPUs
    /// than post-hoc FFD of the round-0 plan.  The winning placement
    /// is stamped into the plan.
    fn place_with_feedback(
        &self,
        plan: &mut ExecutionPlan,
        groups: &[Vec<FragmentSpec>],
        stats: &mut ScheduleStats,
        telemetry: &RepartitionTelemetry,
    ) {
        let popts = &self.opts.placement;
        let g = &self.cm.config().gpu;
        let mut best: Result<Placement, _> =
            place(&self.cm, plan, popts.max_gpus);
        let needs_feedback = match &best {
            Ok(p) => {
                // excess over the larger of the share and memory lower
                // bounds: share-ceiling tightening cannot beat a
                // memory-bound packing, so a memory-bound fleet must
                // not fire futile rounds on every trigger
                let lb = (plan.gpus_share_lower_bound(g.max_share)
                    as usize)
                    .max(super::placement::gpus_mem_lower_bound(
                        &self.cm, plan,
                    ));
                p.excess_over(lb) > popts.frag_threshold
            }
            Err(_) => true,
        };
        if needs_feedback {
            let base = self.opts.repartition.constraints;
            for round in 1..=popts.max_rounds {
                stats.placement_rounds = round;
                // ceiling ladder: max_share/2, /3, … rounded up to the
                // share grid; per-instance memory capped at one GPU so
                // a tightened pass can always be placed
                let unit = g.share_unit.max(1);
                let ceiling = (g.max_share / (round as u32 + 1))
                    .div_ceil(unit)
                    .max(1)
                    * unit;
                let cons = crate::profiler::AllocConstraints {
                    max_share: ceiling.min(base.max_share),
                    max_instance_mem_mb: Some(
                        base.max_instance_mem_mb
                            .map_or(g.gpu_mem_mb, |m| m.min(g.gpu_mem_mb)),
                    ),
                    ..base
                };
                let rep_opts = RepartitionOptions {
                    constraints: cons,
                    ..self.opts.repartition.clone()
                };
                let (cand, _) =
                    self.repartition_pass(groups, &rep_opts, telemetry);
                let Ok(cand_placed) =
                    place(&self.cm, &cand, popts.max_gpus)
                else {
                    continue;
                };
                let accept = match &best {
                    // a GPU-saving tightened plan must not shed clients
                    // and may inflate total share only within the
                    // configured slack (0 by default: the planner stays
                    // share-optimal, so share-metric comparisons against
                    // baselines are unaffected — tightening is accepted
                    // exactly when instance-granularity slack makes the
                    // denser packing free)
                    Ok(p) => {
                        cand.infeasible.len() <= plan.infeasible.len()
                            && cand_placed.gpus() < p.gpus()
                            && cand.total_share() as f64
                                <= plan.total_share() as f64
                                    * (1.0 + popts.share_slack)
                                    + 1e-9
                    }
                    Err(_) => true,
                };
                if accept {
                    *plan = cand;
                    best = Ok(cand_placed);
                    break;
                }
            }
        }
        match &best {
            Ok(p) => {
                stamp(plan, p);
                stats.gpus = p.gpus();
                stats.fragmentation = p.fragmentation(g.max_share);
            }
            // every tightened round failed too (reachable only with a
            // hard `max_gpus` cluster cap or max_rounds = 0: the
            // per-instance mem/share ceilings make unconstrained
            // tightened plans placeable) — surface it instead of
            // masquerading as placement-disabled
            Err(_) => stats.placement_failed = true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;
    use crate::coordinator::repartition::{plan_covers_demand, plan_is_slo_safe};

    fn scheduler() -> Scheduler {
        Scheduler::new(
            CostModel::new(Config::embedded()),
            SchedulerOptions::default(),
        )
    }

    fn demands(cm: &CostModel) -> Vec<FragmentSpec> {
        let inc = cm.model_index("inc").unwrap();
        let vgg = cm.model_index("vgg").unwrap();
        let mut v = Vec::new();
        for i in 0..8 {
            v.push(FragmentSpec::single(
                ClientId(i),
                inc,
                2 + (i as usize % 3),
                90.0 + i as f64,
                30.0,
            ));
        }
        for i in 8..12 {
            v.push(FragmentSpec::single(ClientId(i), vgg, 2, 60.0, 30.0));
        }
        v
    }

    #[test]
    fn plan_is_valid_and_covers_all_clients() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, stats) = s.plan(&d);
        assert!(plan.infeasible.is_empty());
        assert!(plan_is_slo_safe(&plan));
        assert!(plan_covers_demand(&plan));
        assert_eq!(stats.n_input, 12);
        assert!(stats.n_after_merge <= 12);
        let mut clients: Vec<u32> = plan
            .sets
            .iter()
            .flat_map(|s| s.members.iter())
            .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
            .collect();
        clients.sort_unstable();
        assert_eq!(clients, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn models_never_mix_in_a_set() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, _) = s.plan(&d);
        for set in &plan.sets {
            for m in &set.members {
                assert_eq!(m.spec.model, set.model);
            }
        }
    }

    #[test]
    fn merging_reduces_fragment_count() {
        // vgg fragments on TX2-like budgets have a large resource margin
        // (cheap server model, generous SLO), so Uniform+ merging at the
        // default 0.2 threshold must collapse uniform clients.
        let s = scheduler();
        let cm = s.cost_model();
        let vgg = cm.model_index("vgg").unwrap();
        let d: Vec<FragmentSpec> = (0..20)
            .map(|i| FragmentSpec::single(ClientId(i), vgg, 1, 44.0, 30.0))
            .collect();
        let (_, stats) = s.plan(&d);
        assert!(stats.n_after_merge < 20, "{}", stats.n_after_merge);
    }

    #[test]
    fn pool_size_does_not_change_result() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let mk = |pool| {
            Scheduler::new(
                cm.clone(),
                SchedulerOptions { pool_size: pool, ..Default::default() },
            )
            .plan(&d)
            .0
        };
        let a = mk(1);
        let b = mk(4);
        assert_eq!(a.total_share(), b.total_share());
    }

    #[test]
    fn empty_demands_empty_plan() {
        let (plan, stats) = scheduler().plan(&[]);
        assert!(plan.sets.is_empty());
        assert_eq!(stats.n_groups, 0);
    }

    #[test]
    fn replanning_reuses_unchanged_groups() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (first, st1) = s.plan(&d);
        assert_eq!(st1.n_groups_reused, 0);
        assert_eq!(st1.fragments_regrouped, st1.n_after_merge);
        // identical demands: every group replays from the cache …
        let (second, st2) = s.plan(&d);
        assert_eq!(st2.n_groups_reused, st2.n_groups);
        // … the delta-aware grouping regroups nothing …
        assert_eq!(st2.fragments_regrouped, 0);
        assert_eq!(st2.groups_replayed, st2.n_groups);
        assert_eq!(st2.group_fallbacks, 0);
        // … with a byte-identical plan
        assert_eq!(first, second);
    }

    /// Grouping reuse pinned off: the rest of the incremental pipeline
    /// (merge, DP, placement) stays exact — plans byte-identical to a
    /// fresh scheduler after a perturbation.
    #[test]
    fn incremental_matches_from_scratch_after_change() {
        let exact = || {
            Scheduler::new(
                CostModel::new(Config::embedded()),
                SchedulerOptions {
                    group: GroupOptions {
                        incremental: false,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
        };
        let s = exact();
        let mut d = demands(s.cost_model());
        let _ = s.plan(&d);
        // a partition-point change (the re-planning trigger)
        d[0].p = 5;
        d[3].budget_ms += 11.0;
        let (incremental, st) = s.plan(&d);
        // changed groups must not silently replay
        assert!(st.n_groups_reused < st.n_groups || st.n_groups == 0);
        assert_eq!(st.groups_replayed, 0, "grouping reuse is off");
        let fresh = exact().plan(&d).0;
        assert_eq!(incremental, fresh);
    }

    /// Default pipeline (incremental grouping on): a perturbed trigger
    /// no longer promises byte-identity with a fresh plan, but it must
    /// stay a *valid* plan of comparable quality, touching only the
    /// changed fragments.
    #[test]
    fn incremental_grouping_keeps_plan_quality_after_change() {
        let s = scheduler();
        let mut d = demands(s.cost_model());
        let _ = s.plan(&d);
        d[0].p = 5;
        d[3].budget_ms += 11.0;
        let (plan, st) = s.plan(&d);
        assert!(st.fragments_regrouped > 0, "change must be regrouped");
        assert!(st.fragments_regrouped < st.n_after_merge || st.group_fallbacks > 0);
        assert!(plan.infeasible.is_empty());
        assert!(plan_is_slo_safe(&plan));
        assert!(plan_covers_demand(&plan));
        let fresh = scheduler().plan(&d).0;
        assert!(
            plan.total_share() as f64 <= fresh.total_share() as f64 * 1.2,
            "incremental share {} vs fresh {}",
            plan.total_share(),
            fresh.total_share()
        );
    }

    #[test]
    fn non_incremental_mode_never_reuses() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let s = Scheduler::new(
            cm,
            SchedulerOptions { incremental: false, ..Default::default() },
        );
        let (a, _) = s.plan(&d);
        let (b, st) = s.plan(&d);
        assert_eq!(st.n_groups_reused, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn plans_are_placed_by_default() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, stats) = s.plan(&d);
        let gpus = plan.placed_gpus().expect("default planner stamps GPUs");
        assert_eq!(stats.gpus, gpus);
        assert!(
            gpus as u32
                >= plan.gpus_share_lower_bound(
                    s.cost_model().config().gpu.max_share
                )
        );
        let usage = crate::coordinator::placement::stamped_usage(
            s.cost_model(),
            &plan,
        )
        .unwrap();
        let g = &s.cost_model().config().gpu;
        for u in &usage {
            assert!(u.share <= g.max_share);
            // epsilon: stamped_usage re-sums memory in stage order
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6);
        }
    }

    #[test]
    fn placement_disabled_leaves_plan_unstamped() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let off = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                placement: crate::coordinator::PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let (plan, stats) = off.plan(&d);
        assert_eq!(plan.placed_gpus(), None);
        assert_eq!(stats.gpus, 0);
        // tightening rounds only ever move away from the per-fragment
        // optimum, so the placed planner never undercuts the share of
        // the pre-placement plan
        let on = Scheduler::new(cm, SchedulerOptions::default());
        let (placed, _) = on.plan(&d);
        assert!(placed.total_share() >= plan.total_share());
    }

    #[test]
    fn clear_plan_cache_forces_recompute() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (a, _) = s.plan(&d);
        s.clear_plan_cache();
        let (b, st) = s.plan(&d);
        assert_eq!(st.n_groups_reused, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn reuse_counters_track_replan_work() {
        // placement off isolates the merge/repartition counters from
        // feedback-round recomputation; grouping reuse off keeps the
        // final fresh-plan identity assertion exact
        let cm = CostModel::new(Config::embedded());
        let s = Scheduler::new(
            cm,
            SchedulerOptions {
                placement: crate::coordinator::PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                group: GroupOptions { incremental: false, ..Default::default() },
                ..Default::default()
            },
        );
        let mut d = demands(s.cost_model());
        let (_, st1) = s.plan(&d);
        assert!(st1.merge_classes > 0);
        assert_eq!(st1.classes_remerged, st1.merge_classes);
        assert!(st1.grid_points_evaluated > 0);
        // identical trigger: every phase replays
        let (_, st2) = s.plan(&d);
        assert_eq!(st2.classes_remerged, 0);
        assert_eq!(st2.n_groups_reused, st2.n_groups);
        assert_eq!(st2.grid_points_evaluated, 0);
        // a split-point trigger: only the dirty slice re-runs
        d[0].p = 5;
        let (incremental, st3) = s.plan(&d);
        assert!(st3.classes_remerged < st3.merge_classes);
        assert!(st3.grid_points_evaluated > 0);
        let fresh = Scheduler::new(
            CostModel::new(Config::embedded()),
            SchedulerOptions {
                placement: crate::coordinator::PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                group: GroupOptions { incremental: false, ..Default::default() },
                ..Default::default()
            },
        );
        assert_eq!(incremental, fresh.plan(&d).0);
    }

    #[test]
    fn persisted_context_warms_a_restarted_scheduler() {
        let path = std::env::temp_dir().join(format!(
            "graft_replan_ctx_{}.json",
            std::process::id()
        ));
        let s = scheduler();
        let d = demands(s.cost_model());
        let (first, _) = s.plan(&d);
        s.save_replan_context(&path).unwrap();
        // "restart": a fresh scheduler, cold caches, reloaded context
        let s2 = scheduler();
        let (merge_classes, dp_hints) =
            s2.load_replan_context(&path).unwrap();
        assert!(merge_classes > 0, "no merge classes persisted");
        assert!(dp_hints > 0, "no dp hints persisted");
        // the first replan after the restart is warm: merging splices
        // entirely from the reloaded cache and the suffix DP seeds from
        // the reloaded hints — with a byte-identical plan
        let (replanned, st) = s2.plan(&d);
        assert_eq!(st.classes_remerged, 0, "merge cache not warm");
        // the persisted grouping state replays every group untouched
        assert_eq!(st.fragments_regrouped, 0, "grouping state not warm");
        assert_eq!(st.groups_replayed, st.n_groups);
        // a winning standalone fallback is rank-0 (never "hinted"), so
        // warm hits are only guaranteed where the plan truly realigned
        let realigned = first.sets.iter().any(|s| {
            s.members.len() > 1 || s.point != s.members[0].spec.p
        });
        if realigned {
            assert!(st.dp_warm_hits > 0, "dp hints not warm");
        }
        assert_eq!(replanned, first);
        // garbage or missing files fail cleanly
        assert!(s2.load_replan_context(&path.with_extension("nope")).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_replan_context_still_loads() {
        // a pre-incremental-grouping context (schema v1, no "groups"
        // section) must load cleanly; the first replan is merge/DP-warm
        // but grouping-cold
        let path = std::env::temp_dir().join(format!(
            "graft_replan_ctx_v1_{}.json",
            std::process::id()
        ));
        let s = scheduler();
        let d = demands(s.cost_model());
        let _ = s.plan(&d);
        s.save_replan_context(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut doc = crate::util::Json::parse(text.trim()).unwrap();
        if let crate::util::Json::Obj(m) = &mut doc {
            m.insert("schema_version".into(), crate::util::Json::Num(1.0));
            m.remove("groups");
        }
        std::fs::write(&path, format!("{doc}\n")).unwrap();
        let s2 = scheduler();
        let (merge_classes, _) = s2.load_replan_context(&path).unwrap();
        assert!(merge_classes > 0);
        let (_, st) = s2.plan(&d);
        assert_eq!(st.classes_remerged, 0, "merge cache not warm");
        assert_eq!(
            st.fragments_regrouped, st.n_after_merge,
            "v1 context carries no grouping state: cold regroup"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn non_incremental_mode_reports_no_reuse_counters() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let s = Scheduler::new(
            cm,
            SchedulerOptions { incremental: false, ..Default::default() },
        );
        let (_, st) = s.plan(&d);
        assert_eq!(st.merge_classes, 0);
        assert_eq!(st.classes_remerged, 0);
        let (_, st2) = s.plan(&d);
        assert_eq!(st2.dp_warm_hits, 0);
        assert_eq!(st2.n_groups_reused, 0);
        assert_eq!(st2.groups_replayed, 0);
        assert_eq!(st2.fragments_regrouped, 0);
        assert_eq!(st2.group_fallbacks, 0);
    }
}
