//! The Graft scheduler (paper §3/§4): merge → group → re-partition.
//!
//! Takes the live set of fragment demands (one per mobile client), runs
//! the three §4 steps and emits an [`ExecutionPlan`].  Groups are
//! re-aligned in parallel on a configurable thread pool (the paper's
//! "process pool", §5.9/Fig 19b).  The scheduler is cheap enough to be
//! re-invoked on every partition-point change (trigger-based re-planning).

use std::time::Instant;

use super::fragment::FragmentSpec;
use super::grouping::{group_fragments, GroupOptions};
use super::merging::{merge_fragments, MergeOptions};
use super::plan::ExecutionPlan;
use super::repartition::{realign_group, RepartitionOptions};
use crate::profiler::CostModel;
use crate::util::parallel_map;

#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    pub merge: MergeOptions,
    pub group: GroupOptions,
    pub repartition: RepartitionOptions,
    /// Thread-pool size for parallel per-group re-alignment (Fig 19b).
    pub pool_size: usize,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        Self {
            merge: MergeOptions::default(),
            group: GroupOptions::default(),
            repartition: RepartitionOptions::default(),
            pool_size: 2, // paper default (§5.9)
        }
    }
}

/// Timing / size statistics of one scheduling run (Figs 14, 19).
#[derive(Debug, Clone, Default)]
pub struct ScheduleStats {
    pub n_input: usize,
    pub n_after_merge: usize,
    pub n_groups: usize,
    pub merge_ms: f64,
    pub group_ms: f64,
    pub repartition_ms: f64,
    pub total_ms: f64,
}

pub struct Scheduler {
    cm: CostModel,
    pub opts: SchedulerOptions,
}

impl Scheduler {
    pub fn new(cm: CostModel, opts: SchedulerOptions) -> Self {
        Self { cm, opts }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cm
    }

    /// Produce the execution plan for the given demands.
    pub fn plan(&self, demands: &[FragmentSpec]) -> (ExecutionPlan, ScheduleStats) {
        let t0 = Instant::now();
        let mut stats = ScheduleStats {
            n_input: demands.len(),
            ..Default::default()
        };

        // Step 1 — merging (§4.1), per model implicitly via uniformity.
        let t = Instant::now();
        let merged = merge_fragments(&self.cm, demands, &self.opts.merge);
        stats.merge_ms = t.elapsed().as_secs_f64() * 1e3;
        stats.n_after_merge = merged.len();

        // Step 2 — grouping (§4.2), per model (§6: heterogeneous models
        // are separated by type before grouping).
        let t = Instant::now();
        let mut groups: Vec<Vec<FragmentSpec>> = Vec::new();
        let n_models = self.cm.config().models.len();
        for model in 0..n_models {
            let model_specs: Vec<FragmentSpec> = merged
                .iter()
                .filter(|s| s.model == model)
                .cloned()
                .collect();
            if model_specs.is_empty() {
                continue;
            }
            for idx_group in group_fragments(&model_specs, &self.opts.group) {
                groups.push(
                    idx_group.into_iter().map(|i| model_specs[i].clone()).collect(),
                );
            }
        }
        stats.group_ms = t.elapsed().as_secs_f64() * 1e3;
        stats.n_groups = groups.len();

        // Step 3 — re-partitioning (§4.3), groups in parallel.
        let t = Instant::now();
        let plans: Vec<ExecutionPlan> =
            parallel_map(&groups, self.opts.pool_size, |g| {
                realign_group(&self.cm, g, &self.opts.repartition)
            });
        stats.repartition_ms = t.elapsed().as_secs_f64() * 1e3;

        let mut plan = ExecutionPlan::default();
        for p in plans {
            plan.merge_with(p);
        }
        stats.total_ms = t0.elapsed().as_secs_f64() * 1e3;
        (plan, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;
    use crate::coordinator::repartition::{plan_covers_demand, plan_is_slo_safe};

    fn scheduler() -> Scheduler {
        Scheduler::new(
            CostModel::new(Config::embedded()),
            SchedulerOptions::default(),
        )
    }

    fn demands(cm: &CostModel) -> Vec<FragmentSpec> {
        let inc = cm.model_index("inc").unwrap();
        let vgg = cm.model_index("vgg").unwrap();
        let mut v = Vec::new();
        for i in 0..8 {
            v.push(FragmentSpec::single(
                ClientId(i),
                inc,
                2 + (i as usize % 3),
                90.0 + i as f64,
                30.0,
            ));
        }
        for i in 8..12 {
            v.push(FragmentSpec::single(ClientId(i), vgg, 2, 60.0, 30.0));
        }
        v
    }

    #[test]
    fn plan_is_valid_and_covers_all_clients() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, stats) = s.plan(&d);
        assert!(plan.infeasible.is_empty());
        assert!(plan_is_slo_safe(&plan));
        assert!(plan_covers_demand(&plan));
        assert_eq!(stats.n_input, 12);
        assert!(stats.n_after_merge <= 12);
        let mut clients: Vec<u32> = plan
            .sets
            .iter()
            .flat_map(|s| s.members.iter())
            .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
            .collect();
        clients.sort_unstable();
        assert_eq!(clients, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn models_never_mix_in_a_set() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, _) = s.plan(&d);
        for set in &plan.sets {
            for m in &set.members {
                assert_eq!(m.spec.model, set.model);
            }
        }
    }

    #[test]
    fn merging_reduces_fragment_count() {
        // vgg fragments on TX2-like budgets have a large resource margin
        // (cheap server model, generous SLO), so Uniform+ merging at the
        // default 0.2 threshold must collapse uniform clients.
        let s = scheduler();
        let cm = s.cost_model();
        let vgg = cm.model_index("vgg").unwrap();
        let d: Vec<FragmentSpec> = (0..20)
            .map(|i| FragmentSpec::single(ClientId(i), vgg, 1, 44.0, 30.0))
            .collect();
        let (_, stats) = s.plan(&d);
        assert!(stats.n_after_merge < 20, "{}", stats.n_after_merge);
    }

    #[test]
    fn pool_size_does_not_change_result() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let mk = |pool| {
            Scheduler::new(
                cm.clone(),
                SchedulerOptions { pool_size: pool, ..Default::default() },
            )
            .plan(&d)
            .0
        };
        let a = mk(1);
        let b = mk(4);
        assert_eq!(a.total_share(), b.total_share());
    }

    #[test]
    fn empty_demands_empty_plan() {
        let (plan, stats) = scheduler().plan(&[]);
        assert!(plan.sets.is_empty());
        assert_eq!(stats.n_groups, 0);
    }
}
