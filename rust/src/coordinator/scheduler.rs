//! The Graft scheduler (paper §3/§4): merge → group → re-partition →
//! place.
//!
//! Takes the live set of fragment demands (one per mobile client), runs
//! the three §4 steps and emits an [`ExecutionPlan`].  Groups are
//! re-aligned in parallel on a configurable thread pool (the paper's
//! "process pool", §5.9/Fig 19b).  The scheduler is cheap enough to be
//! re-invoked on every partition-point change (trigger-based
//! re-planning), and the whole pipeline is delta-aware across triggers.
//! Merging, re-partitioning and placement reuse are exact (unchanged
//! inputs replay byte-identical outputs, property-tested); grouping
//! reuse is heuristic with an audited quality bound (below):
//!
//! * **merging** re-runs only the uniform classes whose membership
//!   changed, splicing cached outputs for the clean ones
//!   ([`crate::coordinator::merging::merge_fragments_incremental`]);
//! * **grouping** diffs each model's merged fragments against the
//!   previous trigger by member identity: unchanged demands replay the
//!   previous groups byte-identically, and only new/changed fragments
//!   go through the greedy — falling back to the from-scratch greedy
//!   on heavy churn or Eq.-(1) objective drift past ε
//!   ([`crate::coordinator::grouping::group_fragments_incremental`]);
//!   stable groups keep `group_signature`s stable, so the exact caches
//!   below stop churning under small perturbations;
//! * **re-partitioning** replays cached per-group plans for groups
//!   whose exact fragment signature is unchanged, and warm-starts the
//!   suffix DP of the groups that did move from the previous trigger's
//!   chosen re-partition points
//!   ([`crate::coordinator::repartition::realign_group_warm`] — hints
//!   are advisory, keyed by the perturbation-stable
//!   [`crate::coordinator::reuse::warm_signature`]);
//! * the **d_shared grid** search inside each re-alignment is adaptive
//!   (coarse sweep + bound-screened refinement at the same effective
//!   resolution).
//!
//! **Sharded parallel planning.**  Every stage before placement is
//! per-model by construction ([`crate::coordinator::reuse::shard_key`]),
//! so the incremental pipeline partitions the demand into per-model
//! planner shards and runs merge → group → re-partition for each shard
//! on a `planner_threads`-wide worker pool.  Each shard owns its slice
//! of the cross-trigger state (a [`ShardState`]: merge-class cache, DP
//! choice tables, grouping state, exact group-plan cache), checked out
//! of the [`ReplanContext`] for the duration of the trigger — shard
//! workers never contend on a lock.  The per-shard instance streams are
//! concatenated in ascending shard order
//! ([`crate::coordinator::placement::merge_shard_streams`]) and the
//! global FFD placement + feedback loop runs once over the merged
//! stream: bin-packing is a cross-model optimisation, so placement is
//! the one stage that must stay global.  The parallel plan is
//! byte-identical to the `planner_threads = 1` (default) sequential
//! plan — per-model independence makes this exact, property-tested by
//! `prop_sharded_plan_identical_to_sequential`.
//!
//! [`ScheduleStats`] reports per-phase reuse counters plus per-shard
//! wall times so replan cost and shard skew are observable
//! (`graft plan`, `graft bench-scheduler`'s replan + sharded
//! scenarios).
//!
//! Placement (§5.1/§5.3) is part of planning, not an afterthought: the
//! assembled plan is packed onto GPUs first-fit-decreasing under the
//! share + memory caps ([`crate::coordinator::placement`]) and the
//! winning per-instance assignments are stamped into the plan.  When
//! packing fails (an instance no single GPU can host) or fragments
//! badly (placed GPUs far above the share lower bound), the scheduler
//! *re-enters* re-partitioning with tightened per-instance ceilings —
//! splitting fat instances into placeable ones — and keeps a tightened
//! plan only when it strictly reduces the GPU count (or turns an
//! unpackable plan packable), so the integrated planner never does
//! worse than post-hoc FFD packing of the same demand.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::fragment::FragmentSpec;
use super::grouping::{
    group_fragments, group_fragments_incremental, GroupOptions, GroupState,
};
use super::merging::{
    merge_fragments, merge_fragments_incremental, MergeCache, MergeOptions,
};
use super::placement::{
    merge_shard_streams, place, stamp, Placement, PlacementOptions,
};
use super::plan::ExecutionPlan;
use super::repartition::{
    realign_group_warm, RepartitionOptions, RepartitionTelemetry,
};
use super::reuse::{
    group_signature, repartition_signature, shard_key, warm_signature,
};
use crate::profiler::CostModel;
use crate::util::lock::lock_recover;
use crate::util::parallel_map;

#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    pub merge: MergeOptions,
    pub group: GroupOptions,
    pub repartition: RepartitionOptions,
    /// Planner-integrated GPU placement + feedback loop.
    pub placement: PlacementOptions,
    /// Thread-pool size for parallel per-group re-alignment (Fig 19b)
    /// *within* one shard; ignored inside shard workers when
    /// `planner_threads > 1` (parallelism then comes from the shards).
    pub pool_size: usize,
    /// Worker threads for per-model planner shards.  `1` (default) runs
    /// the shards sequentially in shard order — the oracle the parallel
    /// path is property-tested against; any value produces the same
    /// plan byte-for-byte, so this is a latency knob, never a quality
    /// knob.  Sensible values: min(model count, cores) — threads beyond
    /// the shard count idle, and shard wall times are skew-bound (see
    /// [`ScheduleStats::shard_imbalance`]).
    pub planner_threads: usize,
    /// Reuse state across triggers: per-group plans (exact — cache hits
    /// are verified by full spec equality), the dirty-class merge cache,
    /// DP warm hints, and — when `group.incremental` is also set — the
    /// delta-aware grouping state.  With grouping reuse off the whole
    /// incremental pipeline is exact (plans identical to from-scratch
    /// planning, property-tested); with it on, unchanged demands still
    /// replay byte-identical plans while perturbed triggers trade exact
    /// group identity for an ε-audited objective bound.
    pub incremental: bool,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        Self {
            merge: MergeOptions::default(),
            group: GroupOptions::default(),
            repartition: RepartitionOptions::default(),
            placement: PlacementOptions::default(),
            pool_size: 2, // paper default (§5.9)
            planner_threads: 1,
            incremental: true,
        }
    }
}

/// Wall time and sizes of one planner shard within a trigger.
#[derive(Debug, Clone, Default)]
pub struct ShardStat {
    /// Shard key ([`shard_key`]): the model index.
    pub model: usize,
    /// Input demands routed to this shard.
    pub n_specs: usize,
    /// Fragments after the shard's merge pass.
    pub n_merged: usize,
    /// Groups the shard emitted.
    pub n_groups: usize,
    /// Shard wall time (merge + group + re-partition), ms.
    pub ms: f64,
}

/// Timing / size statistics of one scheduling run (Figs 14, 19).
#[derive(Debug, Clone, Default)]
pub struct ScheduleStats {
    pub n_input: usize,
    pub n_after_merge: usize,
    pub n_groups: usize,
    /// Groups served from the incremental cache this trigger.
    pub n_groups_reused: usize,
    /// Per-phase times.  In the sharded pipeline these are summed
    /// across shards (CPU time, not wall time — shards overlap when
    /// `planner_threads > 1`); `total_ms` is always wall time.
    pub merge_ms: f64,
    pub group_ms: f64,
    pub repartition_ms: f64,
    pub placement_ms: f64,
    /// Tightening rounds the placement feedback loop evaluated (0 =
    /// the first placement was accepted as-is).
    pub placement_rounds: usize,
    /// GPUs of the stamped placement (0 when placement is disabled or
    /// the plan is empty).
    pub gpus: usize,
    /// Unused share fraction across those GPUs.
    pub fragmentation: f64,
    /// Placement (and every tightening round) failed — reachable only
    /// under a hard `max_gpus` cluster cap or with `max_rounds = 0`;
    /// the returned plan is unstamped and the executor should expect
    /// to shed load.
    pub placement_failed: bool,
    /// Uniform merge classes the demand set segmented into (incremental
    /// mode only; 0 when `incremental` is off).
    pub merge_classes: usize,
    /// Classes whose membership changed since the previous trigger and
    /// were re-merged (the rest spliced cached results).
    pub classes_remerged: usize,
    /// Groups replayed byte-identically from the previous trigger by
    /// the delta-aware grouping (incremental grouping only; 0 when off).
    pub groups_replayed: usize,
    /// Fragments the delta-aware grouping actually pushed through the
    /// greedy this trigger (new, moved, or — on fallback — the whole
    /// model slice).  0 on an unchanged trigger.
    pub fragments_regrouped: usize,
    /// Model slices where the delta path fell back to the from-scratch
    /// greedy (churn over threshold or ε-audit breach).
    pub group_fallbacks: usize,
    /// Suffix-DP states whose winning choice was seeded from the
    /// previous trigger's re-partition points (warm-started DP).
    pub dp_warm_hits: u64,
    /// d_shared grid points whose member sweep ran, across every
    /// re-aligned group (including placement feedback rounds).
    pub grid_points_evaluated: u64,
    /// Grid points the adaptive search dismissed after the shared-stage
    /// allocation alone.
    pub grid_points_pruned: u64,
    /// Planner shards this trigger ran (one per model with demand; 0 in
    /// non-incremental mode, which plans globally from scratch).
    pub planner_shards: usize,
    /// Wall time of the slowest shard, ms — the lower bound on the
    /// pre-placement wall time at infinite threads.
    pub shard_max_ms: f64,
    /// Shard skew: max / mean shard wall time (1.0 = perfectly
    /// balanced; 0.0 when no shards ran).  High skew means extra
    /// planner threads cannot help — one model dominates the demand.
    pub shard_imbalance: f64,
    /// Per-shard breakdown in ascending shard (model) order.
    pub shards: Vec<ShardStat>,
    pub total_ms: f64,
}

impl ScheduleStats {
    /// Emit this run's numbers as registry gauges
    /// (`graft_scheduler_*`), so the last trigger's cost shows up next
    /// to the serving counters on `/metrics`.
    pub fn collect_metrics(&self, out: &mut Vec<crate::obs::Metric>) {
        let g = |n: &str, v: f64| {
            crate::obs::Metric::gauge(format!("graft_scheduler_{n}"), v)
        };
        out.push(g("input_fragments", self.n_input as f64));
        out.push(g("merged_fragments", self.n_after_merge as f64));
        out.push(g("groups", self.n_groups as f64));
        out.push(g("groups_reused", self.n_groups_reused as f64));
        out.push(g("plan_ms", self.total_ms));
        out.push(g("placement_rounds", self.placement_rounds as f64));
        out.push(g("gpus", self.gpus as f64));
        out.push(g("fragmentation", self.fragmentation));
        out.push(g(
            "placement_failed",
            if self.placement_failed { 1.0 } else { 0.0 },
        ));
        out.push(g("planner_shards", self.planner_shards as f64));
        out.push(g("shard_max_ms", self.shard_max_ms));
    }
}

/// One cached group plan: the exact specs (so signature-hash collisions
/// can never surface a wrong plan), the plan, and the last trigger
/// generation that touched it.
struct CachedGroupPlan {
    specs: Vec<FragmentSpec>,
    plan: ExecutionPlan,
    generation: u64,
}

/// Generational group-plan cache (per shard).  Each trigger syncs the
/// generation and refreshes the entries it hits; when the entry count
/// exceeds the capacity, eviction drops only entries *not* touched
/// within the last trigger — the live working set always survives, so
/// steady-state replay never falls off a clear-everything cliff.
#[derive(Default)]
struct GroupCache {
    map: HashMap<u64, Vec<CachedGroupPlan>>,
    entries: usize,
}

const GROUP_CACHE_CAPACITY: usize = 1 << 16;
const DP_HINT_CAPACITY: usize = 1 << 16;

/// The previous trigger's winning re-partition points for one
/// (approximate) group.
struct DpHintEntry {
    points: Vec<usize>,
    generation: u64,
}

/// One planner shard's slice of the cross-trigger replan state: the
/// dirty-class merge cache, the DP choice tables (keyed by the
/// perturbation-stable [`warm_signature`] — the signature hashes the
/// model, so the global table partitions exactly along the shard key),
/// the previous trigger's grouping state and the exact group-plan
/// cache.  Checked out of the [`ReplanContext`] by `plan()` for the
/// duration of a trigger, so shard workers mutate their state without
/// any cross-shard locking.
#[derive(Default)]
struct ShardState {
    merge: MergeCache,
    dp: HashMap<u64, DpHintEntry>,
    group: Option<GroupState>,
    cache: GroupCache,
    /// Trigger generation of the last checkout (drives eviction).
    generation: u64,
}

impl ShardState {
    /// Open a new trigger generation on this shard's caches: sync the
    /// generation and evict stale entries when over capacity.  Called
    /// once per checkout — the placement feedback rounds within a
    /// trigger share the generation, so the "previous trigger's working
    /// set survives eviction" invariant holds regardless of how many
    /// re-partitioning passes a trigger runs.  (The merge cache bumps
    /// its own generation inside `merge_fragments_incremental`.)
    fn open_generation(&mut self, gen: u64, persist_dirty: &AtomicBool) {
        self.generation = gen;
        if self.cache.entries > GROUP_CACHE_CAPACITY {
            for bucket in self.cache.map.values_mut() {
                bucket.retain(|e| e.generation + 1 >= gen);
            }
            self.cache.map.retain(|_, b| !b.is_empty());
            self.cache.entries =
                self.cache.map.values().map(Vec::len).sum();
        }
        if self.dp.len() > DP_HINT_CAPACITY {
            self.dp.retain(|_, e| e.generation + 1 >= gen);
            // dp tables are persisted — eviction changes the on-disk
            // image (the group-plan cache above is not persisted)
            persist_dirty.store(true, Ordering::Relaxed);
        }
    }
}

/// Cross-trigger replan state: one [`ShardState`] per model ever
/// planned (bounded by the model count), plus the read-only DP hints
/// reloaded from a pre-sharding context file that could not be routed
/// to a shard (warm signatures are opaque hashes — consulted on miss,
/// superseded as soon as each shard refreshes its own table).
struct ReplanContext {
    shards: HashMap<usize, ShardState>,
    dp_fallback: Arc<HashMap<u64, Vec<usize>>>,
    generation: u64,
}

/// A shard's trigger input: its demand slice plus the checked-out
/// state (taken exactly once by the worker that plans the shard).
struct ShardJob {
    model: usize,
    specs: Vec<FragmentSpec>,
    state: Mutex<Option<ShardState>>,
}

/// Everything one shard worker hands back for deterministic
/// concatenation in shard order.
struct ShardOutcome {
    model: usize,
    state: ShardState,
    plan: ExecutionPlan,
    groups: Vec<Vec<FragmentSpec>>,
    n_specs: usize,
    n_merged: usize,
    merge_classes: usize,
    classes_remerged: usize,
    groups_replayed: usize,
    fragments_regrouped: usize,
    group_fallbacks: usize,
    n_groups_reused: usize,
    merge_ms: f64,
    group_ms: f64,
    repartition_ms: f64,
    ms: f64,
}

pub struct Scheduler {
    cm: CostModel,
    pub opts: SchedulerOptions,
    replan: Mutex<ReplanContext>,
    /// Set when a trigger changes any persisted replan state (merge
    /// classes, DP points, grouping state); cleared by a successful
    /// save/load.  Lets `save_replan_context` skip the atomic rewrite
    /// on unchanged triggers — steady-state replans persist nothing.
    persist_dirty: AtomicBool,
}

impl Scheduler {
    pub fn new(cm: CostModel, opts: SchedulerOptions) -> Self {
        Self {
            cm,
            opts,
            replan: Mutex::new(ReplanContext {
                shards: HashMap::new(),
                dp_fallback: Arc::new(HashMap::new()),
                generation: 0,
            }),
            persist_dirty: AtomicBool::new(true),
        }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cm
    }

    /// Persist the cross-trigger replan context (merge-class cache, DP
    /// choice tables, per-model grouping state) as JSON, so a restarted
    /// scheduler's first live replan is still warm.  The exact
    /// group-plan cache is *not* persisted: it stores whole plans
    /// (orders of magnitude bigger) and a cold group recompute is
    /// precisely what the warm DP hints accelerate.  Written atomically
    /// (tmp + rename), so a crash mid-save never leaves a truncated
    /// context.  Returns `false` (skipping the rewrite entirely) when
    /// no trigger changed the persisted state since the last save or
    /// load — the dirty flag makes steady-state replan loops I/O-free.
    /// The per-shard states are serialised into the same globally-keyed
    /// schema v2 layout as before sharding, so contexts round-trip
    /// across planner versions in both directions.
    pub fn save_replan_context(
        &self,
        path: &std::path::Path,
    ) -> anyhow::Result<bool> {
        use crate::util::Json;
        if !self.persist_dirty.load(Ordering::SeqCst) {
            return Ok(false);
        }
        let ctx = lock_recover(&self.replan);
        // models sorted so the file is deterministic for a given state
        let mut models: Vec<usize> = ctx.shards.keys().copied().collect();
        models.sort_unstable();
        let mut merge_classes = Vec::new();
        for &m in &models {
            if let Json::Arr(v) = ctx.shards[&m].merge.to_json() {
                merge_classes.extend(v);
            }
        }
        // dp: the per-shard tables are disjoint (warm signatures hash
        // the model); sorted by signature for determinism
        let mut dp_entries: Vec<(u64, &Vec<usize>)> = models
            .iter()
            .flat_map(|m| {
                ctx.shards[m].dp.iter().map(|(sig, e)| (*sig, &e.points))
            })
            .collect();
        dp_entries.sort_unstable_by_key(|e| e.0);
        let dp: Vec<Json> = dp_entries
            .into_iter()
            .map(|(sig, points)| {
                let mut o = std::collections::BTreeMap::new();
                o.insert("sig".into(), Json::Str(format!("{sig:016x}")));
                o.insert(
                    "points".into(),
                    Json::Arr(
                        points.iter().map(|&p| Json::Num(p as f64)).collect(),
                    ),
                );
                Json::Obj(o)
            })
            .collect();
        let groups: Vec<Json> = models
            .iter()
            .filter_map(|&m| {
                ctx.shards[&m].group.as_ref().map(|state| {
                    let mut o = std::collections::BTreeMap::new();
                    o.insert("model".into(), Json::Num(m as f64));
                    o.insert("state".into(), state.to_json());
                    Json::Obj(o)
                })
            })
            .collect();
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("context".into(), Json::Str("replan".into()));
        doc.insert("schema_version".into(), Json::Num(2.0));
        doc.insert("merge".into(), Json::Arr(merge_classes));
        doc.insert("dp".into(), Json::Arr(dp));
        doc.insert("groups".into(), Json::Arr(groups));
        // clear under the lock: a racing trigger that mutates state
        // after this snapshot re-dirties the flag for the next save
        self.persist_dirty.store(false, Ordering::SeqCst);
        drop(ctx);
        let write = || -> anyhow::Result<()> {
            let tmp = path.with_extension("tmp");
            std::fs::write(&tmp, format!("{}\n", Json::Obj(doc)))?;
            std::fs::rename(&tmp, path)?;
            Ok(())
        };
        match write() {
            Ok(()) => Ok(true),
            Err(e) => {
                self.persist_dirty.store(true, Ordering::SeqCst);
                Err(e)
            }
        }
    }

    /// Reload a context saved by [`Self::save_replan_context`] into
    /// this scheduler, replacing its current replan state.  Returns
    /// `(merge classes, dp hints)` loaded.  Accepts schema v1 (pre
    /// incremental grouping — no `groups` section) and v2.  The
    /// globally keyed merge cache is split per model onto the planner
    /// shards (classes never span models, so the re-keying is exact);
    /// DP hints cannot be routed from their opaque signatures alone and
    /// load into a read-only fallback table every shard consults on
    /// miss.  Safe against stale or mismatched files: merge entries are
    /// verified by full spec equality on every lookup, DP hints are
    /// advisory, and grouping state is diffed by member identity (a
    /// stale state just shows up as churn), so the worst a wrong
    /// context can do is miss.
    pub fn load_replan_context(
        &self,
        path: &std::path::Path,
    ) -> anyhow::Result<(usize, usize)> {
        use crate::util::Json;
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(text.trim())?;
        if doc.get("context")?.as_str()? != "replan" {
            anyhow::bail!("not a replan context file");
        }
        let version = doc.get("schema_version")?.as_usize()?;
        if !(1..=2).contains(&version) {
            anyhow::bail!("unsupported replan-context schema v{version}");
        }
        let merge = MergeCache::from_json(doc.get("merge")?)?;
        let mut dp_fallback = HashMap::new();
        for e in doc.get("dp")?.as_arr()? {
            let sig = u64::from_str_radix(e.get("sig")?.as_str()?, 16)?;
            dp_fallback.insert(sig, e.get("points")?.as_usize_vec()?);
        }
        let counts = (merge.len(), dp_fallback.len());
        let mut shards: HashMap<usize, ShardState> = HashMap::new();
        for (model, mc) in merge.split_by_model() {
            shards.entry(model).or_default().merge = mc;
        }
        if version >= 2 {
            for e in doc.get("groups")?.as_arr()? {
                shards
                    .entry(e.get("model")?.as_usize()?)
                    .or_default()
                    .group = Some(GroupState::from_json(e.get("state")?)?);
            }
        }
        let mut ctx = lock_recover(&self.replan);
        ctx.shards = shards;
        ctx.dp_fallback = Arc::new(dp_fallback);
        ctx.generation = 0;
        drop(ctx);
        // in-memory state now mirrors the file: nothing to rewrite
        self.persist_dirty.store(false, Ordering::SeqCst);
        Ok(counts)
    }

    /// Drop all incrementally cached replan state — group plans, merge
    /// classes and DP choice tables (e.g. after mutating `opts` —
    /// signatures also cover the options, so this is belt-and-braces,
    /// not correctness).
    pub fn clear_plan_cache(&self) {
        let mut ctx = lock_recover(&self.replan);
        ctx.shards.clear();
        ctx.dp_fallback = Arc::new(HashMap::new());
        drop(ctx);
        self.persist_dirty.store(true, Ordering::SeqCst);
    }

    /// Produce the execution plan for the given demands.  Incremental
    /// mode (the default) plans per-model shards on
    /// `opts.planner_threads` workers and merges the streams — plans
    /// are byte-identical at every thread count.
    pub fn plan(&self, demands: &[FragmentSpec]) -> (ExecutionPlan, ScheduleStats) {
        if !self.opts.incremental {
            return self.plan_from_scratch(demands);
        }
        let t0 = Instant::now();
        let mut stats = ScheduleStats {
            n_input: demands.len(),
            ..Default::default()
        };

        // Partition the demand into per-model planner shards.  The
        // BTreeMap fixes ascending shard order; within a shard the
        // input order is preserved, and the per-shard merge sort is
        // stable, so shard-local sorting concatenated in shard order
        // equals the global sort — the root of byte-identity.
        let mut by_model: BTreeMap<usize, Vec<FragmentSpec>> = BTreeMap::new();
        for d in demands {
            by_model.entry(shard_key(d)).or_default().push(d.clone());
        }
        // One trigger generation shared by every shard and by the
        // placement feedback rounds within the trigger; shard states
        // are checked out here and returned after placement.
        let (gen, fallback, jobs) = {
            let mut ctx = lock_recover(&self.replan);
            ctx.generation += 1;
            let jobs: Vec<ShardJob> = by_model
                .into_iter()
                .map(|(model, specs)| ShardJob {
                    model,
                    specs,
                    state: Mutex::new(Some(
                        ctx.shards.remove(&model).unwrap_or_default(),
                    )),
                })
                .collect();
            (ctx.generation, ctx.dp_fallback.clone(), jobs)
        };
        // with shard-level parallelism the per-group pool inside each
        // worker stays serial; at planner_threads = 1 the single
        // sequential shard pass keeps the per-group pool (Fig 19b)
        let inner = if self.opts.planner_threads > 1 {
            1
        } else {
            self.opts.pool_size
        };
        let telemetry = RepartitionTelemetry::default();
        let outcomes: Vec<ShardOutcome> =
            parallel_map(&jobs, self.opts.planner_threads, |job| {
                self.plan_shard(job, gen, &fallback, inner, &telemetry)
            });

        // Deterministic concatenation: parallel_map preserves input
        // (ascending shard) order regardless of completion order.
        let mut shard_plans: Vec<ExecutionPlan> = Vec::new();
        let mut groups: Vec<Vec<FragmentSpec>> = Vec::new();
        let mut shard_states: Vec<(usize, ShardState)> = Vec::new();
        for o in outcomes {
            stats.n_after_merge += o.n_merged;
            stats.merge_classes += o.merge_classes;
            stats.classes_remerged += o.classes_remerged;
            stats.groups_replayed += o.groups_replayed;
            stats.fragments_regrouped += o.fragments_regrouped;
            stats.group_fallbacks += o.group_fallbacks;
            stats.n_groups += o.groups.len();
            stats.n_groups_reused += o.n_groups_reused;
            stats.merge_ms += o.merge_ms;
            stats.group_ms += o.group_ms;
            stats.repartition_ms += o.repartition_ms;
            stats.shards.push(ShardStat {
                model: o.model,
                n_specs: o.n_specs,
                n_merged: o.n_merged,
                n_groups: o.groups.len(),
                ms: o.ms,
            });
            shard_plans.push(o.plan);
            groups.extend(o.groups);
            shard_states.push((o.model, o.state));
        }
        stats.planner_shards = stats.shards.len();
        stats.shard_max_ms =
            stats.shards.iter().map(|s| s.ms).fold(0.0, f64::max);
        let mean = if stats.shards.is_empty() {
            0.0
        } else {
            stats.shards.iter().map(|s| s.ms).sum::<f64>()
                / stats.shards.len() as f64
        };
        stats.shard_imbalance = if stats.shards.is_empty() {
            0.0
        } else if mean > 0.0 {
            stats.shard_max_ms / mean
        } else {
            1.0
        };
        let mut plan = merge_shard_streams(shard_plans);

        // Step 4 — placement (§5.1/§5.3): the one global stage.  Pack
        // the merged stream onto GPUs and feed fragmentation /
        // unplaceability back into (per-shard) re-partitioning.
        if self.opts.placement.enabled {
            let t = Instant::now();
            self.place_with_feedback(
                &mut plan,
                &groups,
                &mut shard_states,
                &fallback,
                &mut stats,
                &telemetry,
            );
            stats.placement_ms = t.elapsed().as_secs_f64() * 1e3;
        }

        // return the shard states for the next trigger
        {
            let mut ctx = lock_recover(&self.replan);
            for (model, state) in shard_states {
                ctx.shards.insert(model, state);
            }
        }

        stats.dp_warm_hits = telemetry.dp_warm_hits.load(Ordering::Relaxed);
        stats.grid_points_evaluated =
            telemetry.grid_points_evaluated.load(Ordering::Relaxed);
        stats.grid_points_pruned =
            telemetry.grid_points_pruned.load(Ordering::Relaxed);
        stats.total_ms = t0.elapsed().as_secs_f64() * 1e3;
        (plan, stats)
    }

    /// One shard's trigger: merge → group → re-partition over its
    /// demand slice, against its own checked-out state.  Runs on a
    /// shard worker — everything it touches is shard-local (the
    /// repartition telemetry is atomic), so no locks are taken.
    fn plan_shard(
        &self,
        job: &ShardJob,
        gen: u64,
        fallback: &HashMap<u64, Vec<usize>>,
        inner_threads: usize,
        telemetry: &RepartitionTelemetry,
    ) -> ShardOutcome {
        let t_shard = Instant::now();
        let mut state = lock_recover(&job.state)
            .take()
            .expect("shard state checked out exactly once");
        state.open_generation(gen, &self.persist_dirty);

        // Step 1 — merging (§4.1): re-merge only the dirty uniform
        // classes of this model.
        let t = Instant::now();
        let out = merge_fragments_incremental(
            &self.cm,
            &job.specs,
            &self.opts.merge,
            &mut state.merge,
        );
        let merge_ms = t.elapsed().as_secs_f64() * 1e3;
        if out.classes_remerged > 0 {
            self.persist_dirty.store(true, Ordering::Relaxed);
        }
        let merged = out.merged;

        // Step 2 — grouping (§4.2).  The shard is one model, so the
        // whole merged slice groups in one pass; specs are then *moved*
        // into their groups.
        let t = Instant::now();
        let mut groups_replayed = 0;
        let mut fragments_regrouped = 0;
        let mut group_fallbacks = 0;
        let idx_groups: Vec<Vec<usize>> = if self.opts.group.incremental {
            let (delta, gstate) = group_fragments_incremental(
                &merged,
                &self.opts.group,
                state.group.as_ref(),
            );
            groups_replayed = delta.replayed;
            fragments_regrouped = delta.regrouped;
            if delta.fell_back {
                group_fallbacks = 1;
            }
            if delta.regrouped > 0 || delta.fell_back || state.group.is_none()
            {
                self.persist_dirty.store(true, Ordering::Relaxed);
            }
            state.group = Some(gstate);
            delta.groups
        } else {
            group_fragments(&merged, &self.opts.group)
        };
        let n_merged = merged.len();
        let mut slots: Vec<Option<FragmentSpec>> =
            merged.into_iter().map(Some).collect();
        let groups: Vec<Vec<FragmentSpec>> = idx_groups
            .into_iter()
            .map(|ig| {
                ig.into_iter()
                    .map(|i| {
                        slots[i].take().expect("fragment in exactly one group")
                    })
                    .collect()
            })
            .collect();
        let group_ms = t.elapsed().as_secs_f64() * 1e3;

        // Step 3 — re-partitioning (§4.3): unchanged groups replay
        // their cached sets, the rest re-align with the previous
        // trigger's DP choices as warm hints.
        let t = Instant::now();
        let (plan, n_groups_reused) = self.repartition_shard(
            &groups,
            &self.opts.repartition,
            telemetry,
            &mut state,
            fallback,
            inner_threads,
        );
        let repartition_ms = t.elapsed().as_secs_f64() * 1e3;

        ShardOutcome {
            model: job.model,
            state,
            plan,
            groups,
            n_specs: job.specs.len(),
            n_merged,
            merge_classes: out.classes,
            classes_remerged: out.classes_remerged,
            groups_replayed,
            fragments_regrouped,
            group_fallbacks,
            n_groups_reused,
            merge_ms,
            group_ms,
            repartition_ms,
            ms: t_shard.elapsed().as_secs_f64() * 1e3,
        }
    }

    /// One re-partitioning pass over one shard's groups with the given
    /// options (the feedback loop calls this again with tightened
    /// constraints — each options signature keeps its own cache
    /// entries).  Returns the assembled plan and the reused-group
    /// count.  Lock-free: the shard state is owned by the caller.
    fn repartition_shard(
        &self,
        groups: &[Vec<FragmentSpec>],
        rep_opts: &RepartitionOptions,
        telemetry: &RepartitionTelemetry,
        state: &mut ShardState,
        fallback: &HashMap<u64, Vec<usize>>,
        threads: usize,
    ) -> (ExecutionPlan, usize) {
        let opts_sig = repartition_signature(rep_opts);
        // one warm-signature hash per group, shared by the hint lookup
        // and the end-of-pass DP table refresh
        let warm_sigs: Vec<u64> =
            groups.iter().map(|g| warm_signature(g, opts_sig)).collect();
        let mut reused: Vec<Option<ExecutionPlan>> = vec![None; groups.len()];
        let mut hints: Vec<Option<Vec<usize>>> = vec![None; groups.len()];
        let gen = state.generation;
        for (gi, g) in groups.iter().enumerate() {
            if let Some(bucket) =
                state.cache.map.get_mut(&group_signature(g, opts_sig))
            {
                if let Some(e) = bucket.iter_mut().find(|e| &e.specs == g) {
                    e.generation = gen;
                    reused[gi] = Some(e.plan.clone());
                }
            }
            // warm DP hints for the groups that must recompute
            if reused[gi].is_none() {
                if let Some(e) = state.dp.get(&warm_sigs[gi]) {
                    hints[gi] = Some(e.points.clone());
                } else if let Some(p) = fallback.get(&warm_sigs[gi]) {
                    hints[gi] = Some(p.clone());
                }
            }
        }
        let todo: Vec<(usize, &Vec<FragmentSpec>)> = groups
            .iter()
            .enumerate()
            .filter(|(gi, _)| reused[*gi].is_none())
            .collect();
        let computed: Vec<ExecutionPlan> =
            parallel_map(&todo, threads, |(gi, g)| {
                realign_group_warm(
                    &self.cm,
                    g.as_slice(),
                    rep_opts,
                    hints[*gi].as_deref(),
                    Some(telemetry),
                )
            });
        let mut computed = computed.into_iter();
        let mut plan = ExecutionPlan::default();
        let mut n_reused = 0;
        for (gi, cached) in reused.into_iter().enumerate() {
            let p = match cached {
                Some(p) => {
                    n_reused += 1;
                    p
                }
                None => {
                    let p = computed
                        .next()
                        .expect("one computed plan per uncached group");
                    // fresh plans enter the exact group cache (not
                    // persisted — no dirty marking needed here)
                    state
                        .cache
                        .map
                        .entry(group_signature(&groups[gi], opts_sig))
                        .or_default()
                        .push(CachedGroupPlan {
                            specs: groups[gi].clone(),
                            plan: p.clone(),
                            generation: gen,
                        });
                    state.cache.entries += 1;
                    p
                }
            };
            // every group (fresh or replayed) refreshes its DP choice
            // table for the next trigger; latest trigger wins — hints
            // are advisory, one entry per warm key is enough.  Only an
            // actual point change dirties the persisted image.
            let points = p.realign_points();
            if state.dp.get(&warm_sigs[gi]).map(|e| &e.points)
                != Some(&points)
            {
                self.persist_dirty.store(true, Ordering::Relaxed);
            }
            state
                .dp
                .insert(warm_sigs[gi], DpHintEntry { points, generation: gen });
            plan.merge_with(p);
        }
        (plan, n_reused)
    }

    /// Re-partition every group with the given options.  Incremental
    /// mode routes each shard's contiguous group run to its own state
    /// (sequentially — this only runs on the main thread inside the
    /// placement feedback loop, where the per-group pool provides the
    /// parallelism); non-incremental mode realigns everything from
    /// scratch.
    fn repartition_all(
        &self,
        groups: &[Vec<FragmentSpec>],
        rep_opts: &RepartitionOptions,
        telemetry: &RepartitionTelemetry,
        shards: &mut [(usize, ShardState)],
        fallback: &HashMap<u64, Vec<usize>>,
    ) -> (ExecutionPlan, usize) {
        if !self.opts.incremental {
            let todo: Vec<&Vec<FragmentSpec>> = groups.iter().collect();
            let computed: Vec<ExecutionPlan> =
                parallel_map(&todo, self.opts.pool_size, |g| {
                    realign_group_warm(
                        &self.cm,
                        g.as_slice(),
                        rep_opts,
                        None,
                        Some(telemetry),
                    )
                });
            return (merge_shard_streams(computed), 0);
        }
        let mut shard_plans = Vec::with_capacity(shards.len());
        let mut n_reused = 0;
        let mut gi = 0;
        for (model, state) in shards.iter_mut() {
            let start = gi;
            while gi < groups.len() && shard_key(&groups[gi][0]) == *model {
                gi += 1;
            }
            let (p, r) = self.repartition_shard(
                &groups[start..gi],
                rep_opts,
                telemetry,
                state,
                fallback,
                self.opts.pool_size,
            );
            shard_plans.push(p);
            n_reused += r;
        }
        debug_assert_eq!(gi, groups.len(), "groups must partition by shard");
        (merge_shard_streams(shard_plans), n_reused)
    }

    /// The non-incremental reference pipeline: global merge, from-
    /// scratch grouping, stateless re-partitioning — single-threaded
    /// apart from the per-group pool.  This is the oracle the
    /// incremental sharded path is property-tested against.
    fn plan_from_scratch(
        &self,
        demands: &[FragmentSpec],
    ) -> (ExecutionPlan, ScheduleStats) {
        let t0 = Instant::now();
        let mut stats = ScheduleStats {
            n_input: demands.len(),
            ..Default::default()
        };

        // Step 1 — merging (§4.1), per model implicitly via uniformity.
        let t = Instant::now();
        let merged = merge_fragments(&self.cm, demands, &self.opts.merge);
        stats.merge_ms = t.elapsed().as_secs_f64() * 1e3;
        stats.n_after_merge = merged.len();

        // Step 2 — grouping (§4.2), per model (§6: heterogeneous models
        // are separated by type before grouping).  `merged` is sorted by
        // model, so each model is a contiguous slice — grouped in place,
        // then the specs are *moved* into their groups.
        let t = Instant::now();
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        let mut start = 0;
        for i in 1..=merged.len() {
            if i == merged.len() || merged[i].model != merged[start].model {
                ranges.push((start, i));
                start = i;
            }
        }
        let mut idx_groups: Vec<Vec<usize>> = Vec::new();
        for &(a, b) in &ranges {
            for idx_group in group_fragments(&merged[a..b], &self.opts.group)
            {
                idx_groups
                    .push(idx_group.into_iter().map(|i| a + i).collect());
            }
        }
        let mut slots: Vec<Option<FragmentSpec>> =
            merged.into_iter().map(Some).collect();
        let groups: Vec<Vec<FragmentSpec>> = idx_groups
            .into_iter()
            .map(|ig| {
                ig.into_iter()
                    .map(|i| {
                        slots[i].take().expect("fragment in exactly one group")
                    })
                    .collect()
            })
            .collect();
        stats.group_ms = t.elapsed().as_secs_f64() * 1e3;
        stats.n_groups = groups.len();

        // Step 3 — re-partitioning (§4.3), from scratch.
        let t = Instant::now();
        let telemetry = RepartitionTelemetry::default();
        let no_fallback = HashMap::new();
        let (mut plan, _) = self.repartition_all(
            &groups,
            &self.opts.repartition,
            &telemetry,
            &mut [],
            &no_fallback,
        );
        stats.repartition_ms = t.elapsed().as_secs_f64() * 1e3;

        // Step 4 — placement (§5.1/§5.3).
        if self.opts.placement.enabled {
            let t = Instant::now();
            self.place_with_feedback(
                &mut plan,
                &groups,
                &mut [],
                &no_fallback,
                &mut stats,
                &telemetry,
            );
            stats.placement_ms = t.elapsed().as_secs_f64() * 1e3;
        }

        stats.dp_warm_hits = telemetry.dp_warm_hits.load(Ordering::Relaxed);
        stats.grid_points_evaluated =
            telemetry.grid_points_evaluated.load(Ordering::Relaxed);
        stats.grid_points_pruned =
            telemetry.grid_points_pruned.load(Ordering::Relaxed);
        stats.total_ms = t0.elapsed().as_secs_f64() * 1e3;
        (plan, stats)
    }

    /// The placement feedback loop.  Round 0 places the plan as
    /// emitted; when that is unplaceable or fragments beyond the
    /// configured threshold, up to `max_rounds` re-partitioning passes
    /// run with progressively tighter per-instance ceilings
    /// (`max_share` halved/thirded, per-instance memory capped at one
    /// GPU).  A tightened plan is kept only when it strictly lowers
    /// the GPU count without shedding clients, or turns an unpackable
    /// plan packable — so the final plan never packs onto more GPUs
    /// than post-hoc FFD of the round-0 plan.  The winning placement
    /// is stamped into the plan.
    fn place_with_feedback(
        &self,
        plan: &mut ExecutionPlan,
        groups: &[Vec<FragmentSpec>],
        shards: &mut [(usize, ShardState)],
        fallback: &HashMap<u64, Vec<usize>>,
        stats: &mut ScheduleStats,
        telemetry: &RepartitionTelemetry,
    ) {
        let popts = &self.opts.placement;
        let g = &self.cm.config().gpu;
        let mut best: Result<Placement, _> =
            place(&self.cm, plan, popts.max_gpus);
        let needs_feedback = match &best {
            Ok(p) => {
                // excess over the larger of the share and memory lower
                // bounds: share-ceiling tightening cannot beat a
                // memory-bound packing, so a memory-bound fleet must
                // not fire futile rounds on every trigger
                let lb = (plan.gpus_share_lower_bound(g.max_share)
                    as usize)
                    .max(super::placement::gpus_mem_lower_bound(
                        &self.cm, plan,
                    ));
                p.excess_over(lb) > popts.frag_threshold
            }
            Err(_) => true,
        };
        if needs_feedback {
            let base = self.opts.repartition.constraints;
            for round in 1..=popts.max_rounds {
                stats.placement_rounds = round;
                // ceiling ladder: max_share/2, /3, … rounded up to the
                // share grid; per-instance memory capped at one GPU so
                // a tightened pass can always be placed
                let unit = g.share_unit.max(1);
                let ceiling = (g.max_share / (round as u32 + 1))
                    .div_ceil(unit)
                    .max(1)
                    * unit;
                let cons = crate::profiler::AllocConstraints {
                    max_share: ceiling.min(base.max_share),
                    max_instance_mem_mb: Some(
                        base.max_instance_mem_mb
                            .map_or(g.gpu_mem_mb, |m| m.min(g.gpu_mem_mb)),
                    ),
                    ..base
                };
                let rep_opts = RepartitionOptions {
                    constraints: cons,
                    ..self.opts.repartition.clone()
                };
                let (cand, _) = self.repartition_all(
                    groups, &rep_opts, telemetry, shards, fallback,
                );
                let Ok(cand_placed) =
                    place(&self.cm, &cand, popts.max_gpus)
                else {
                    continue;
                };
                let accept = match &best {
                    // a GPU-saving tightened plan must not shed clients
                    // and may inflate total share only within the
                    // configured slack (0 by default: the planner stays
                    // share-optimal, so share-metric comparisons against
                    // baselines are unaffected — tightening is accepted
                    // exactly when instance-granularity slack makes the
                    // denser packing free)
                    Ok(p) => {
                        cand.infeasible.len() <= plan.infeasible.len()
                            && cand_placed.gpus() < p.gpus()
                            && cand.total_share() as f64
                                <= plan.total_share() as f64
                                    * (1.0 + popts.share_slack)
                                    + 1e-9
                    }
                    Err(_) => true,
                };
                if accept {
                    *plan = cand;
                    best = Ok(cand_placed);
                    break;
                }
            }
        }
        match &best {
            Ok(p) => {
                stamp(plan, p);
                stats.gpus = p.gpus();
                stats.fragmentation = p.fragmentation(g.max_share);
            }
            // every tightened round failed too (reachable only with a
            // hard `max_gpus` cluster cap or max_rounds = 0: the
            // per-instance mem/share ceilings make unconstrained
            // tightened plans placeable) — surface it instead of
            // masquerading as placement-disabled
            Err(_) => stats.placement_failed = true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;
    use crate::coordinator::repartition::{plan_covers_demand, plan_is_slo_safe};

    fn scheduler() -> Scheduler {
        Scheduler::new(
            CostModel::new(Config::embedded()),
            SchedulerOptions::default(),
        )
    }

    fn demands(cm: &CostModel) -> Vec<FragmentSpec> {
        let inc = cm.model_index("inc").unwrap();
        let vgg = cm.model_index("vgg").unwrap();
        let mut v = Vec::new();
        for i in 0..8 {
            v.push(FragmentSpec::single(
                ClientId(i),
                inc,
                2 + (i as usize % 3),
                90.0 + i as f64,
                30.0,
            ));
        }
        for i in 8..12 {
            v.push(FragmentSpec::single(ClientId(i), vgg, 2, 60.0, 30.0));
        }
        v
    }

    #[test]
    fn plan_is_valid_and_covers_all_clients() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, stats) = s.plan(&d);
        assert!(plan.infeasible.is_empty());
        assert!(plan_is_slo_safe(&plan));
        assert!(plan_covers_demand(&plan));
        assert_eq!(stats.n_input, 12);
        assert!(stats.n_after_merge <= 12);
        let mut clients: Vec<u32> = plan
            .sets
            .iter()
            .flat_map(|s| s.members.iter())
            .flat_map(|m| m.spec.clients.iter().map(|c| c.0))
            .collect();
        clients.sort_unstable();
        assert_eq!(clients, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn models_never_mix_in_a_set() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, _) = s.plan(&d);
        for set in &plan.sets {
            for m in &set.members {
                assert_eq!(m.spec.model, set.model);
            }
        }
    }

    #[test]
    fn merging_reduces_fragment_count() {
        // vgg fragments on TX2-like budgets have a large resource margin
        // (cheap server model, generous SLO), so Uniform+ merging at the
        // default 0.2 threshold must collapse uniform clients.
        let s = scheduler();
        let cm = s.cost_model();
        let vgg = cm.model_index("vgg").unwrap();
        let d: Vec<FragmentSpec> = (0..20)
            .map(|i| FragmentSpec::single(ClientId(i), vgg, 1, 44.0, 30.0))
            .collect();
        let (_, stats) = s.plan(&d);
        assert!(stats.n_after_merge < 20, "{}", stats.n_after_merge);
    }

    #[test]
    fn pool_size_does_not_change_result() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let mk = |pool| {
            Scheduler::new(
                cm.clone(),
                SchedulerOptions { pool_size: pool, ..Default::default() },
            )
            .plan(&d)
            .0
        };
        let a = mk(1);
        let b = mk(4);
        assert_eq!(a.total_share(), b.total_share());
    }

    #[test]
    fn planner_threads_do_not_change_result() {
        // the sharded-planning determinism contract, cold and warm —
        // plans are byte-identical at every thread count
        let cm = CostModel::new(Config::embedded());
        let mut d = demands(&cm);
        let mk = |threads| {
            Scheduler::new(
                cm.clone(),
                SchedulerOptions {
                    planner_threads: threads,
                    ..Default::default()
                },
            )
        };
        let seq = mk(1);
        let par = mk(4);
        let (a, sa) = seq.plan(&d);
        let (b, sb) = par.plan(&d);
        assert_eq!(a, b, "cold plans diverged");
        assert_eq!(sa.planner_shards, 2, "two models -> two shards");
        assert_eq!(sa.planner_shards, sb.planner_shards);
        // a perturbed (warm) trigger stays identical too
        d[0].p = 5;
        d[3].budget_ms += 11.0;
        let (wa, _) = seq.plan(&d);
        let (wb, _) = par.plan(&d);
        assert_eq!(wa, wb, "warm plans diverged");
    }

    #[test]
    fn shard_stats_surface_skew() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (_, st) = s.plan(&d);
        assert_eq!(st.planner_shards, 2);
        assert_eq!(st.shards.len(), 2);
        assert!(
            st.shards[0].model < st.shards[1].model,
            "shards must be in ascending (deterministic) order"
        );
        assert_eq!(
            st.shards.iter().map(|s| s.n_specs).sum::<usize>(),
            st.n_input
        );
        assert_eq!(
            st.shards.iter().map(|s| s.n_groups).sum::<usize>(),
            st.n_groups
        );
        let max = st.shards.iter().map(|s| s.ms).fold(0.0, f64::max);
        assert_eq!(st.shard_max_ms, max);
        assert!(st.shard_imbalance >= 1.0 - 1e-9);
    }

    #[test]
    fn empty_demands_empty_plan() {
        let (plan, stats) = scheduler().plan(&[]);
        assert!(plan.sets.is_empty());
        assert_eq!(stats.n_groups, 0);
        assert_eq!(stats.planner_shards, 0);
    }

    #[test]
    fn replanning_reuses_unchanged_groups() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (first, st1) = s.plan(&d);
        assert_eq!(st1.n_groups_reused, 0);
        assert_eq!(st1.fragments_regrouped, st1.n_after_merge);
        // identical demands: every group replays from the cache …
        let (second, st2) = s.plan(&d);
        assert_eq!(st2.n_groups_reused, st2.n_groups);
        // … the delta-aware grouping regroups nothing …
        assert_eq!(st2.fragments_regrouped, 0);
        assert_eq!(st2.groups_replayed, st2.n_groups);
        assert_eq!(st2.group_fallbacks, 0);
        // … with a byte-identical plan
        assert_eq!(first, second);
    }

    /// Grouping reuse pinned off: the rest of the incremental pipeline
    /// (merge, DP, placement) stays exact — plans byte-identical to a
    /// fresh scheduler after a perturbation.
    #[test]
    fn incremental_matches_from_scratch_after_change() {
        let exact = || {
            Scheduler::new(
                CostModel::new(Config::embedded()),
                SchedulerOptions {
                    group: GroupOptions {
                        incremental: false,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            )
        };
        let s = exact();
        let mut d = demands(s.cost_model());
        let _ = s.plan(&d);
        // a partition-point change (the re-planning trigger)
        d[0].p = 5;
        d[3].budget_ms += 11.0;
        let (incremental, st) = s.plan(&d);
        // changed groups must not silently replay
        assert!(st.n_groups_reused < st.n_groups || st.n_groups == 0);
        assert_eq!(st.groups_replayed, 0, "grouping reuse is off");
        let fresh = exact().plan(&d).0;
        assert_eq!(incremental, fresh);
    }

    /// Default pipeline (incremental grouping on): a perturbed trigger
    /// no longer promises byte-identity with a fresh plan, but it must
    /// stay a *valid* plan of comparable quality, touching only the
    /// changed fragments.
    #[test]
    fn incremental_grouping_keeps_plan_quality_after_change() {
        let s = scheduler();
        let mut d = demands(s.cost_model());
        let _ = s.plan(&d);
        d[0].p = 5;
        d[3].budget_ms += 11.0;
        let (plan, st) = s.plan(&d);
        assert!(st.fragments_regrouped > 0, "change must be regrouped");
        assert!(st.fragments_regrouped < st.n_after_merge || st.group_fallbacks > 0);
        assert!(plan.infeasible.is_empty());
        assert!(plan_is_slo_safe(&plan));
        assert!(plan_covers_demand(&plan));
        let fresh = scheduler().plan(&d).0;
        assert!(
            plan.total_share() as f64 <= fresh.total_share() as f64 * 1.2,
            "incremental share {} vs fresh {}",
            plan.total_share(),
            fresh.total_share()
        );
    }

    #[test]
    fn non_incremental_mode_never_reuses() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let s = Scheduler::new(
            cm,
            SchedulerOptions { incremental: false, ..Default::default() },
        );
        let (a, _) = s.plan(&d);
        let (b, st) = s.plan(&d);
        assert_eq!(st.n_groups_reused, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn plans_are_placed_by_default() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (plan, stats) = s.plan(&d);
        let gpus = plan.placed_gpus().expect("default planner stamps GPUs");
        assert_eq!(stats.gpus, gpus);
        assert!(
            gpus as u32
                >= plan.gpus_share_lower_bound(
                    s.cost_model().config().gpu.max_share
                )
        );
        let usage = crate::coordinator::placement::stamped_usage(
            s.cost_model(),
            &plan,
        )
        .unwrap();
        let g = &s.cost_model().config().gpu;
        for u in &usage {
            assert!(u.share <= g.max_share);
            // epsilon: stamped_usage re-sums memory in stage order
            assert!(u.mem_mb <= g.gpu_mem_mb + 1e-6);
        }
    }

    #[test]
    fn placement_disabled_leaves_plan_unstamped() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let off = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                placement: crate::coordinator::PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let (plan, stats) = off.plan(&d);
        assert_eq!(plan.placed_gpus(), None);
        assert_eq!(stats.gpus, 0);
        // tightening rounds only ever move away from the per-fragment
        // optimum, so the placed planner never undercuts the share of
        // the pre-placement plan
        let on = Scheduler::new(cm, SchedulerOptions::default());
        let (placed, _) = on.plan(&d);
        assert!(placed.total_share() >= plan.total_share());
    }

    #[test]
    fn clear_plan_cache_forces_recompute() {
        let s = scheduler();
        let d = demands(s.cost_model());
        let (a, _) = s.plan(&d);
        s.clear_plan_cache();
        let (b, st) = s.plan(&d);
        assert_eq!(st.n_groups_reused, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn reuse_counters_track_replan_work() {
        // placement off isolates the merge/repartition counters from
        // feedback-round recomputation; grouping reuse off keeps the
        // final fresh-plan identity assertion exact
        let cm = CostModel::new(Config::embedded());
        let s = Scheduler::new(
            cm,
            SchedulerOptions {
                placement: crate::coordinator::PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                group: GroupOptions { incremental: false, ..Default::default() },
                ..Default::default()
            },
        );
        let mut d = demands(s.cost_model());
        let (_, st1) = s.plan(&d);
        assert!(st1.merge_classes > 0);
        assert_eq!(st1.classes_remerged, st1.merge_classes);
        assert!(st1.grid_points_evaluated > 0);
        // identical trigger: every phase replays
        let (_, st2) = s.plan(&d);
        assert_eq!(st2.classes_remerged, 0);
        assert_eq!(st2.n_groups_reused, st2.n_groups);
        assert_eq!(st2.grid_points_evaluated, 0);
        // a split-point trigger: only the dirty slice re-runs
        d[0].p = 5;
        let (incremental, st3) = s.plan(&d);
        assert!(st3.classes_remerged < st3.merge_classes);
        assert!(st3.grid_points_evaluated > 0);
        let fresh = Scheduler::new(
            CostModel::new(Config::embedded()),
            SchedulerOptions {
                placement: crate::coordinator::PlacementOptions {
                    enabled: false,
                    ..Default::default()
                },
                group: GroupOptions { incremental: false, ..Default::default() },
                ..Default::default()
            },
        );
        assert_eq!(incremental, fresh.plan(&d).0);
    }

    #[test]
    fn persisted_context_warms_a_restarted_scheduler() {
        let path = std::env::temp_dir().join(format!(
            "graft_replan_ctx_{}.json",
            std::process::id()
        ));
        let s = scheduler();
        let d = demands(s.cost_model());
        let (first, _) = s.plan(&d);
        s.save_replan_context(&path).unwrap();
        // "restart": a fresh scheduler, cold caches, reloaded context
        let s2 = scheduler();
        let (merge_classes, dp_hints) =
            s2.load_replan_context(&path).unwrap();
        assert!(merge_classes > 0, "no merge classes persisted");
        assert!(dp_hints > 0, "no dp hints persisted");
        // the first replan after the restart is warm: merging splices
        // entirely from the reloaded cache and the suffix DP seeds from
        // the reloaded hints — with a byte-identical plan
        let (replanned, st) = s2.plan(&d);
        assert_eq!(st.classes_remerged, 0, "merge cache not warm");
        // the persisted grouping state replays every group untouched
        assert_eq!(st.fragments_regrouped, 0, "grouping state not warm");
        assert_eq!(st.groups_replayed, st.n_groups);
        // a winning standalone fallback is rank-0 (never "hinted"), so
        // warm hits are only guaranteed where the plan truly realigned
        let realigned = first.sets.iter().any(|s| {
            s.members.len() > 1 || s.point != s.members[0].spec.p
        });
        if realigned {
            assert!(st.dp_warm_hits > 0, "dp hints not warm");
        }
        assert_eq!(replanned, first);
        // garbage or missing files fail cleanly
        assert!(s2.load_replan_context(&path.with_extension("nope")).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_skips_rewrite_when_state_unchanged() {
        // the dirty flag: unchanged replan state skips the atomic
        // rewrite entirely
        let path = std::env::temp_dir().join(format!(
            "graft_replan_ctx_dirty_{}.json",
            std::process::id()
        ));
        let s = scheduler();
        let d = demands(s.cost_model());
        let _ = s.plan(&d);
        assert!(s.save_replan_context(&path).unwrap(), "first save writes");
        assert!(
            !s.save_replan_context(&path).unwrap(),
            "clean state must skip the rewrite"
        );
        // an unchanged replay leaves the context clean
        let _ = s.plan(&d);
        assert!(
            !s.save_replan_context(&path).unwrap(),
            "steady-state replay dirtied the context"
        );
        // a real change dirties it again
        let mut d2 = d.clone();
        d2[0].p = 5;
        let _ = s.plan(&d2);
        assert!(s.save_replan_context(&path).unwrap(), "change must persist");
        // a freshly loaded context mirrors the file: nothing to rewrite
        let s2 = scheduler();
        s2.load_replan_context(&path).unwrap();
        assert!(!s2.save_replan_context(&path).unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_replan_context_still_loads() {
        // a pre-incremental-grouping context (schema v1, no "groups"
        // section) must load cleanly; the first replan is merge/DP-warm
        // but grouping-cold
        let path = std::env::temp_dir().join(format!(
            "graft_replan_ctx_v1_{}.json",
            std::process::id()
        ));
        let s = scheduler();
        let d = demands(s.cost_model());
        let _ = s.plan(&d);
        s.save_replan_context(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut doc = crate::util::Json::parse(text.trim()).unwrap();
        if let crate::util::Json::Obj(m) = &mut doc {
            m.insert("schema_version".into(), crate::util::Json::Num(1.0));
            m.remove("groups");
        }
        std::fs::write(&path, format!("{doc}\n")).unwrap();
        let s2 = scheduler();
        let (merge_classes, _) = s2.load_replan_context(&path).unwrap();
        assert!(merge_classes > 0);
        let (_, st) = s2.plan(&d);
        assert_eq!(st.classes_remerged, 0, "merge cache not warm");
        assert_eq!(
            st.fragments_regrouped, st.n_after_merge,
            "v1 context carries no grouping state: cold regroup"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn non_incremental_mode_reports_no_reuse_counters() {
        let cm = CostModel::new(Config::embedded());
        let d = demands(&cm);
        let s = Scheduler::new(
            cm,
            SchedulerOptions { incremental: false, ..Default::default() },
        );
        let (_, st) = s.plan(&d);
        assert_eq!(st.merge_classes, 0);
        assert_eq!(st.classes_remerged, 0);
        assert_eq!(st.planner_shards, 0, "scratch mode plans globally");
        let (_, st2) = s.plan(&d);
        assert_eq!(st2.dp_warm_hits, 0);
        assert_eq!(st2.n_groups_reused, 0);
        assert_eq!(st2.groups_replayed, 0);
        assert_eq!(st2.fragments_regrouped, 0);
        assert_eq!(st2.group_fallbacks, 0);
    }
}
