//! §6 "Realignment disruption" — realignment reuse across triggers.
//!
//! Two reuse mechanisms live here:
//!
//! 1. **Shadow instances** ([`attach_fragment`] / [`detach_client`]).
//!    When fragments arrive or change faster than the scheduler
//!    re-plans, the paper proposes attaching the newcomer to an
//!    *existing* re-aligned set whose members are "similar" (same
//!    partition point, approximate time budget), exploiting the
//!    resource-margin discreteness: the set's provisioned instances
//!    usually absorb the extra rate for free.  If no compatible set has
//!    margin, the newcomer gets a standalone *shadow instance* until
//!    the next full re-plan.
//! 2. **Replan signatures** ([`group_signature`], [`warm_signature`],
//!    [`repartition_signature`]).  The deterministic hashes the
//!    scheduler's trigger-to-trigger caches key on: the exact group
//!    signature (every spec field — replayed plans are verified by full
//!    spec equality, so collisions can never surface a wrong plan) and
//!    the *perturbation-stable* warm signature (model + client ids
//!    only) that finds the previous trigger's DP choices again after
//!    members merely moved their split points or budgets.  Warm hits
//!    are advisory — they seed the suffix DP's incumbent, never replace
//!    the search — so warm signatures need no collision verification.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use super::fragment::FragmentSpec;
use super::plan::{ExecutionPlan, MemberPlan};
use super::repartition::{standalone_set, RepartitionOptions};
use crate::profiler::{AllocConstraints, CostModel};

/// The planner-shard key of one fragment demand: the model index.
/// Every pre-placement stage is per-model by construction — uniform
/// merge classes never span models, groups are formed within a model
/// slice, and re-alignment operates per group — and both cache
/// signatures below hash the model, so the scheduler's cross-trigger
/// state partitions exactly along this key.  Planning the shards
/// independently and concatenating their instance streams in ascending
/// key order reproduces the sequential pipeline byte-for-byte.
pub fn shard_key(spec: &FragmentSpec) -> usize {
    spec.model
}

/// Deterministic signature of one group's exact fragment demands (plus
/// the re-partition options that shape its plan).  Keys the scheduler's
/// exact group-plan cache.
pub fn group_signature(specs: &[FragmentSpec], opts_sig: u64) -> u64 {
    let mut h = DefaultHasher::new();
    opts_sig.hash(&mut h);
    specs.len().hash(&mut h);
    for s in specs {
        s.model.hash(&mut h);
        s.p.hash(&mut h);
        s.budget_ms.to_bits().hash(&mut h);
        s.rate_rps.to_bits().hash(&mut h);
        s.clients.len().hash(&mut h);
        for c in &s.clients {
            c.0.hash(&mut h);
        }
    }
    h.finish()
}

/// Perturbation-stable signature of a group: the model and the sorted
/// client-id set only.  Partition points, budgets and rates are
/// deliberately excluded, so a group whose members moved their split
/// point (the re-planning trigger) still finds the previous trigger's
/// DP choice table.  Advisory-only: a collision at worst seeds a
/// useless incumbent, never a wrong plan.
pub fn warm_signature(specs: &[FragmentSpec], opts_sig: u64) -> u64 {
    let mut h = DefaultHasher::new();
    opts_sig.hash(&mut h);
    specs.first().map_or(usize::MAX, |s| s.model).hash(&mut h);
    let mut clients: Vec<u32> = specs
        .iter()
        .flat_map(|s| s.clients.iter().map(|c| c.0))
        .collect();
    clients.sort_unstable();
    clients.hash(&mut h);
    h.finish()
}

/// Signature of the grouping options that shape the incremental
/// grouping state ([`crate::coordinator::grouping::GroupState`]): a
/// persisted or cached state built under different knobs must miss, so
/// an options change falls back to the from-scratch greedy instead of
/// replaying groups the current settings would never have formed.
/// `dense_limit` is deliberately excluded — it changes the similarity
/// lookup's build cost, never the resulting groups.
pub fn group_options_signature(
    opts: &crate::coordinator::grouping::GroupOptions,
) -> u64 {
    let mut h = DefaultHasher::new();
    opts.group_size.hash(&mut h);
    opts.weights.p.to_bits().hash(&mut h);
    opts.weights.t.to_bits().hash(&mut h);
    opts.weights.q.to_bits().hash(&mut h);
    opts.seed.hash(&mut h);
    opts.churn_threshold.to_bits().hash(&mut h);
    opts.epsilon.to_bits().hash(&mut h);
    opts.audit_limit.hash(&mut h);
    h.finish()
}

/// Fold an [`AllocConstraints`] into a signature hasher (shared by the
/// re-partition and merge option signatures so a new constraint field
/// is added in exactly one place).
pub(crate) fn hash_constraints(h: &mut DefaultHasher, cons: &AllocConstraints) {
    cons.max_instances.hash(h);
    cons.max_batch.hash(h);
    cons.mem_budget_mb.map(f64::to_bits).hash(h);
    cons.max_share.hash(h);
    cons.max_instance_mem_mb.map(f64::to_bits).hash(h);
}

/// Signature of the re-partition options that shape a group's plan
/// (folded into both group signatures above).
pub fn repartition_signature(opts: &RepartitionOptions) -> u64 {
    let mut h = DefaultHasher::new();
    opts.d_grid.hash(&mut h);
    opts.coarse_grid.hash(&mut h);
    opts.adaptive_grid.hash(&mut h);
    hash_constraints(&mut h, &opts.constraints);
    match &opts.point_set {
        None => 0u8.hash(&mut h),
        Some(ps) => {
            1u8.hash(&mut h);
            ps.hash(&mut h);
        }
    }
    h.finish()
}

/// Outcome of an incremental attach.
#[derive(Debug, Clone, PartialEq)]
pub enum AttachOutcome {
    /// Absorbed by the re-aligned set at this index — no new resources.
    Reused { set: usize },
    /// Provisioned a standalone shadow set (appended to the plan).
    Shadow { set: usize },
    /// Cannot be served at all (budget infeasible even standalone).
    Infeasible,
}

/// Budget tolerance for "similar" fragments (relative).
const BUDGET_SIMILARITY: f64 = 0.15;

/// Try to serve `spec` on an existing plan without re-planning.
///
/// Reuse conditions (paper §6): a set of the same model whose
/// re-partition point is reachable (`spec.p <= point`, and equal when
/// the set has no alignment stages for that point), whose members'
/// *minimum* budget is approximately `spec`'s or looser-compatible, and
/// whose shared stage still has enough throughput margin to absorb the
/// extra rate within its latency envelope.
pub fn attach_fragment(
    cm: &CostModel,
    plan: &mut ExecutionPlan,
    spec: &FragmentSpec,
    cons: &AllocConstraints,
) -> AttachOutcome {
    // 1. look for a reusable set
    let mut best: Option<(usize, f64)> = None; // (set idx, spare rps)
    for (i, set) in plan.sets.iter().enumerate() {
        if set.model != spec.model {
            continue;
        }
        // exact alignment only: the newcomer must enter at the set's
        // re-partition point (no new alignment instances without a plan)
        if spec.p != set.point {
            continue;
        }
        // budget similarity: the set was sized for its members' tightest
        // budget; the newcomer must not be tighter than that envelope
        let t_min = set
            .members
            .iter()
            .map(|m| m.spec.budget_ms)
            .fold(f64::INFINITY, f64::min);
        if spec.budget_ms < t_min * (1.0 - BUDGET_SIMILARITY) {
            continue;
        }
        // margin: shared stage absorbs the extra rate for free
        let spare = set.shared.alloc.throughput_rps - set.shared.demand_rps;
        if spare >= spec.rate_rps && best.map_or(true, |(_, s)| spare > s) {
            best = Some((i, spare));
        }
    }
    if let Some((i, _)) = best {
        let set = &mut plan.sets[i];
        set.shared.demand_rps += spec.rate_rps;
        set.members.push(MemberPlan { spec: spec.clone(), align: None });
        return AttachOutcome::Reused { set: i };
    }

    // 2. shadow instance fallback
    match standalone_set(cm, spec, cons) {
        Some(set) => {
            plan.sets.push(set);
            AttachOutcome::Shadow { set: plan.sets.len() - 1 }
        }
        None => {
            plan.infeasible.push(spec.clone());
            AttachOutcome::Infeasible
        }
    }
}

/// Remove a departed client from the plan (the inverse trigger).  Sets
/// left empty are dropped; returns whether the client was found.
pub fn detach_client(
    plan: &mut ExecutionPlan,
    client: super::fragment::ClientId,
) -> bool {
    let mut found = false;
    for set in &mut plan.sets {
        set.members.retain_mut(|m| {
            let had = m.spec.clients.contains(&client);
            if had {
                found = true;
                set.shared.demand_rps =
                    (set.shared.demand_rps - m.spec.rate_rps).max(0.0);
            }
            !had || m.spec.clients.len() > 1
        });
    }
    plan.sets.retain(|s| !s.members.is_empty());
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::fragment::ClientId;
    use crate::coordinator::repartition::{
        plan_covers_demand, realign_group, RepartitionOptions,
    };

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn base_plan(cm: &CostModel) -> (ExecutionPlan, usize) {
        let mi = cm.model_index("vgg").unwrap();
        let specs = vec![
            FragmentSpec::single(ClientId(0), mi, 1, 90.0, 30.0),
            FragmentSpec::single(ClientId(1), mi, 1, 95.0, 30.0),
        ];
        let plan = realign_group(cm, &specs, &RepartitionOptions::default());
        assert!(plan.infeasible.is_empty());
        (plan, mi)
    }

    #[test]
    fn similar_fragment_is_reused_for_free() {
        let cm = cm();
        let (mut plan, mi) = base_plan(&cm);
        let before = plan.total_share();
        // pick the point of an existing set so reuse is possible
        let point = plan.sets[0].point;
        let margin = plan.sets[0].shared.alloc.throughput_rps
            - plan.sets[0].shared.demand_rps;
        let newcomer = FragmentSpec::single(
            ClientId(9),
            mi,
            point,
            92.0,
            (margin * 0.8).max(1.0),
        );
        let out = attach_fragment(
            &cm,
            &mut plan,
            &newcomer,
            &AllocConstraints::default(),
        );
        assert!(matches!(out, AttachOutcome::Reused { .. }), "{out:?}");
        assert_eq!(plan.total_share(), before, "reuse must be free");
        assert!(plan_covers_demand(&plan));
    }

    #[test]
    fn incompatible_fragment_gets_shadow_instance() {
        let cm = cm();
        let (mut plan, mi) = base_plan(&cm);
        let before_sets = plan.sets.len();
        let before_share = plan.total_share();
        // different partition point -> cannot reuse
        let newcomer =
            FragmentSpec::single(ClientId(9), mi, 3, 70.0, 30.0);
        let out = attach_fragment(
            &cm,
            &mut plan,
            &newcomer,
            &AllocConstraints::default(),
        );
        assert!(matches!(out, AttachOutcome::Shadow { .. }), "{out:?}");
        assert_eq!(plan.sets.len(), before_sets + 1);
        assert!(plan.total_share() > before_share);
    }

    #[test]
    fn tighter_budget_is_not_reused() {
        let cm = cm();
        let (mut plan, mi) = base_plan(&cm);
        let point = plan.sets[0].point;
        // far tighter budget than the set was sized for
        let newcomer = FragmentSpec::single(ClientId(9), mi, point, 20.0, 5.0);
        let out = attach_fragment(
            &cm,
            &mut plan,
            &newcomer,
            &AllocConstraints::default(),
        );
        assert!(!matches!(out, AttachOutcome::Reused { .. }), "{out:?}");
    }

    #[test]
    fn hopeless_fragment_is_infeasible() {
        let cm = cm();
        let (mut plan, mi) = base_plan(&cm);
        let newcomer =
            FragmentSpec::single(ClientId(9), mi, 1, 0.001, 30.0);
        let out = attach_fragment(
            &cm,
            &mut plan,
            &newcomer,
            &AllocConstraints::default(),
        );
        assert_eq!(out, AttachOutcome::Infeasible);
        assert_eq!(plan.infeasible.len(), 1);
    }

    #[test]
    fn warm_signature_survives_split_point_and_budget_moves() {
        let mi = 1usize;
        let a = vec![
            FragmentSpec::single(ClientId(3), mi, 2, 90.0, 30.0),
            FragmentSpec::single(ClientId(7), mi, 4, 70.0, 10.0),
        ];
        // the re-planning trigger: members moved p / budget, same clients
        let mut b = a.clone();
        b[0].p = 5;
        b[1].budget_ms = 120.0;
        assert_eq!(warm_signature(&a, 9), warm_signature(&b, 9));
        // exact signature must differ (the group really changed) …
        assert_ne!(group_signature(&a, 9), group_signature(&b, 9));
        // … and membership changes break the warm key
        let mut c = a.clone();
        c[1].clients = vec![ClientId(8)];
        assert_ne!(warm_signature(&a, 9), warm_signature(&c, 9));
        // options fold into both
        assert_ne!(warm_signature(&a, 9), warm_signature(&a, 10));
    }

    #[test]
    fn repartition_signature_covers_grid_options() {
        let base = RepartitionOptions::default();
        let finer = RepartitionOptions { d_grid: 96, ..base.clone() };
        let exhaustive =
            RepartitionOptions { adaptive_grid: false, ..base.clone() };
        assert_ne!(repartition_signature(&base), repartition_signature(&finer));
        assert_ne!(
            repartition_signature(&base),
            repartition_signature(&exhaustive)
        );
    }

    #[test]
    fn detach_removes_member_and_demand() {
        let cm = cm();
        let (mut plan, _) = base_plan(&cm);
        let total_before: f64 =
            plan.sets.iter().map(|s| s.shared.demand_rps).sum();
        assert!(detach_client(&mut plan, ClientId(0)));
        let total_after: f64 =
            plan.sets.iter().map(|s| s.shared.demand_rps).sum();
        assert!(total_after < total_before);
        assert!(!detach_client(&mut plan, ClientId(77)));
        // all-members-removed sets disappear
        let mut plan2 = plan.clone();
        detach_client(&mut plan2, ClientId(1));
        assert!(plan2
            .sets
            .iter()
            .all(|s| !s.members.is_empty()));
    }
}
