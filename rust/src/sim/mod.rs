//! Simulators: discrete-event latency simulation (Figs 8–10, 18), the
//! GPU energy model (Fig 21), and cluster packing with share/memory
//! caps (Fig 17, §5.3 memory bottlenecks).

pub mod cluster;
pub mod energy;
pub mod latency;

pub use cluster::{pack, Packing, PlacedInstance};
pub use energy::{energy_per_request_j, plan_energy_j};
pub use latency::{simulate, SimClient, SimOptions, SimResult};
