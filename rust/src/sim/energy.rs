//! GPU energy model (Fig 21).
//!
//! Per instance: static power `p_base_w` for the whole provisioning
//! window plus dynamic power proportional to its GPU share scaled by its
//! utilisation (fraction of time actually executing = demand/achievable
//! throughput).  Bigger batches raise achievable throughput per share
//! point, which is why heavy merging (GSLICE⁺) can beat Graft on energy
//! even while losing on allocated share (paper §5.11).

use crate::coordinator::plan::ExecutionPlan;
use crate::profiler::CostModel;

/// Energy (J) consumed by a plan over `duration_s` seconds.
pub fn plan_energy_j(
    cm: &CostModel,
    plan: &ExecutionPlan,
    duration_s: f64,
) -> f64 {
    let g = &cm.config().gpu;
    plan.stages()
        .map(|s| {
            let util =
                (s.demand_rps / s.alloc.throughput_rps).clamp(0.0, 1.0);
            let inst = s.alloc.instances as f64;
            let dynamic =
                g.p_share_w_per_pct * s.alloc.share as f64 * util * inst;
            let statik = g.p_base_w * inst;
            (dynamic + statik) * duration_s
        })
        .sum()
}

/// Energy per served request (J/req) — the figure's comparable unit.
pub fn energy_per_request_j(
    cm: &CostModel,
    plan: &ExecutionPlan,
    duration_s: f64,
) -> f64 {
    let total_rate: f64 = plan
        .sets
        .iter()
        .map(|s| s.shared.demand_rps)
        .sum();
    if total_rate <= 0.0 {
        return f64::NAN;
    }
    plan_energy_j(cm, plan, duration_s) / (total_rate * duration_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::baselines::{gslice, gslice_plus};
    use crate::coordinator::{ClientId, FragmentSpec};
    use crate::profiler::AllocConstraints;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn uniform(cm: &CostModel, n: u32) -> Vec<FragmentSpec> {
        let vgg = cm.model_index("vgg").unwrap();
        (0..n)
            .map(|i| FragmentSpec::single(ClientId(i), vgg, 1, 44.0, 30.0))
            .collect()
    }

    #[test]
    fn energy_scales_with_duration() {
        let cm = cm();
        let plan = gslice(&cm, &uniform(&cm, 4), &AllocConstraints::default());
        let e1 = plan_energy_j(&cm, &plan, 10.0);
        let e2 = plan_energy_j(&cm, &plan, 20.0);
        assert!((e2 - 2.0 * e1).abs() < 1e-9);
        assert!(e1 > 0.0);
    }

    #[test]
    fn merging_reduces_energy() {
        // GSLICE+ merges uniform fragments -> bigger batches -> fewer
        // instances and higher utilisation -> less energy (paper §5.11).
        let cm = cm();
        let specs = uniform(&cm, 8);
        let cons = AllocConstraints::default();
        let e_gslice =
            plan_energy_j(&cm, &gslice(&cm, &specs, &cons), 10.0);
        let e_plus =
            plan_energy_j(&cm, &gslice_plus(&cm, &specs, &cons), 10.0);
        assert!(
            e_plus < e_gslice,
            "gslice+ {e_plus} >= gslice {e_gslice}"
        );
    }

    #[test]
    fn per_request_energy_is_finite() {
        let cm = cm();
        let plan = gslice(&cm, &uniform(&cm, 4), &AllocConstraints::default());
        let e = energy_per_request_j(&cm, &plan, 10.0);
        assert!(e.is_finite() && e > 0.0);
        let empty = ExecutionPlan::default();
        assert!(energy_per_request_j(&cm, &empty, 10.0).is_nan());
    }
}
