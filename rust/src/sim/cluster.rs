//! GPU cluster packing: place plan instances onto GPUs respecting the
//! per-GPU share cap (≤100%, §5.1) and memory capacity.  Used by the
//! capped-resource experiments (Fig 17) and the large-scale memory
//! bottleneck notes of §5.3.
//!
//! This is the *offline reference oracle*: the planner-integrated
//! placement pass lives in [`crate::coordinator::placement`] (grown
//! from this module) and is property-tested to never use more GPUs
//! than post-hoc [`pack`]ing of the same demand.

use crate::coordinator::plan::ExecutionPlan;
use crate::profiler::CostModel;

/// One placed instance.
#[derive(Debug, Clone)]
pub struct PlacedInstance {
    pub gpu: usize,
    pub share: u32,
    pub mem_mb: f64,
}

/// Result of packing a plan onto GPUs.
#[derive(Debug, Clone, Default)]
pub struct Packing {
    pub gpus: usize,
    pub placements: Vec<PlacedInstance>,
    /// Per-GPU (share used, memory used).
    pub usage: Vec<(u32, f64)>,
}

impl Packing {
    /// Unused share fraction across the packed GPUs (0 for an empty
    /// packing); shares the metric definition with the planner-side
    /// `Placement::fragmentation`.
    pub fn fragmentation(&self, max_share: u32) -> f64 {
        let used: u64 = self.usage.iter().map(|(s, _)| *s as u64).sum();
        crate::coordinator::placement::share_fragmentation(
            used,
            self.usage.len(),
            max_share,
        )
    }
}

/// First-fit-decreasing packing of every instance in the plan.
/// Returns `None` if some instance cannot fit on any GPU at all (share
/// or memory above a single GPU's capacity).
pub fn pack(
    cm: &CostModel,
    plan: &ExecutionPlan,
    max_gpus: Option<usize>,
) -> Option<Packing> {
    let g = &cm.config().gpu;
    // expand stages into instances
    let mut items: Vec<(u32, f64)> = Vec::new();
    for s in plan.stages() {
        let mem = cm.instance_mem_mb(s.frag, s.alloc.batch);
        if s.alloc.share > g.max_share || mem > g.gpu_mem_mb {
            return None;
        }
        for _ in 0..s.alloc.instances {
            items.push((s.alloc.share, mem));
        }
    }
    items.sort_by(|a, b| b.0.cmp(&a.0).then(b.1.total_cmp(&a.1)));

    let mut usage: Vec<(u32, f64)> = Vec::new();
    let mut placements = Vec::new();
    for (share, mem) in items {
        let slot = usage.iter().position(|(s, m)| {
            s + share <= g.max_share && m + mem <= g.gpu_mem_mb
        });
        let gpu = match slot {
            Some(i) => i,
            None => {
                if let Some(cap) = max_gpus {
                    if usage.len() >= cap {
                        return None; // does not fit the cluster
                    }
                }
                usage.push((0, 0.0));
                usage.len() - 1
            }
        };
        usage[gpu].0 += share;
        usage[gpu].1 += mem;
        placements.push(PlacedInstance { gpu, share, mem_mb: mem });
    }
    Some(Packing { gpus: usage.len(), placements, usage })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::baselines::gslice;
    use crate::coordinator::{ClientId, FragmentSpec};
    use crate::profiler::AllocConstraints;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    fn plan(cm: &CostModel, n: u32) -> ExecutionPlan {
        let inc = cm.model_index("inc").unwrap();
        let specs: Vec<FragmentSpec> = (0..n)
            .map(|i| FragmentSpec::single(ClientId(i), inc, 3, 100.0, 30.0))
            .collect();
        gslice(cm, &specs, &AllocConstraints::default())
    }

    #[test]
    fn packing_respects_caps() {
        let cm = cm();
        let the_plan = plan(&cm, 12);
        let p = pack(&cm, &the_plan, None).unwrap();
        let g = &cm.config().gpu;
        assert!(p.gpus >= 1);
        for (share, mem) in &p.usage {
            assert!(*share <= g.max_share);
            assert!(*mem <= g.gpu_mem_mb);
        }
        let placed: u32 = p.placements.iter().map(|i| i.share).sum();
        assert_eq!(placed, the_plan.total_share());
    }

    #[test]
    fn gpu_cap_rejects_oversized_plans() {
        let cm = cm();
        let big = plan(&cm, 40);
        assert!(pack(&cm, &big, Some(1)).is_none());
        assert!(pack(&cm, &big, None).is_some());
    }

    #[test]
    fn more_demand_needs_more_gpus() {
        let cm = cm();
        let small = pack(&cm, &plan(&cm, 4), None).unwrap();
        let large = pack(&cm, &plan(&cm, 40), None).unwrap();
        assert!(large.gpus >= small.gpus);
    }
}
