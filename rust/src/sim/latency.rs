//! Discrete-event latency simulator.
//!
//! Replays request arrivals through the stages of an [`ExecutionPlan`]
//! with the same batching semantics as the real executor (greedy
//! batches: an idle instance serves immediately; batches form while all
//! instances are busy), producing the end-to-end latency distributions
//! of Figs 8–10 at scales the real data path cannot host (the paper hit
//! the same wall — §5.3 "we were not able to obtain the end-to-end
//! latency distribution due to the lack of GPU memory").

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use crate::coordinator::plan::ExecutionPlan;
use crate::metrics::LatencyStats;
use crate::profiler::{CostModel, FragmentId};
use crate::workload::{arrivals, ArrivalProcess};

/// One client's arrival context.
#[derive(Debug, Clone)]
pub struct SimClient {
    pub client_id: u32,
    /// Mobile + uplink latency added before the server (ms).
    pub upstream_ms: f64,
    /// End-to-end SLO (ms).
    pub slo_ms: f64,
    /// Server-side budget (ms) used for drop decisions.
    pub budget_ms: f64,
    pub rate_rps: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    pub horizon_s: f64,
    pub seed: u64,
    pub drop_on_slo: bool,
    pub process: ArrivalProcess,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self {
            horizon_s: 20.0,
            seed: 0xD15C,
            drop_on_slo: true,
            process: ArrivalProcess::Periodic { jitter: 0.05 },
        }
    }
}

/// Simulation outcome.
#[derive(Debug, Default)]
pub struct SimResult {
    /// End-to-end latency samples of *served* requests (ms).
    pub e2e: LatencyStats,
    /// Per-client latency stats.
    pub per_client: Vec<(u32, LatencyStats)>,
    pub served: usize,
    pub dropped: usize,
    /// Fraction of served requests within their SLO.
    pub slo_attainment: f64,
}

// -- internal event machinery ------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    Arrival { stage: usize, job: usize },
    Depart { stage: usize, instance: usize },
}

#[derive(Debug, Clone, Copy)]
struct Event {
    t_ms: f64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t_ms == other.t_ms && self.kind == other.kind
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        // min-heap over time
        other.t_ms.total_cmp(&self.t_ms)
    }
}

struct Job {
    client: usize,
    /// Time the request reached the server (ms).
    server_arrival_ms: f64,
    /// Modeled server time accumulated in completed stages (ms).
    accumulated_ms: f64,
}

struct StageState {
    frag: FragmentId,
    share: u32,
    max_batch: u32,
    idle: u32,
    queue: VecDeque<usize>,
    /// Jobs in service per instance slot (batch), with finish event.
    next: Option<usize>,
    in_service: Vec<Vec<usize>>,
}

/// Run the DES for a plan.  `clients[i].client_id` must match the plan's
/// member client ids.
pub fn simulate(
    cm: &CostModel,
    plan: &ExecutionPlan,
    clients: &[SimClient],
    opts: &SimOptions,
) -> SimResult {
    // stage layout mirroring serving::Server::start
    let mut stages: Vec<StageState> = Vec::new();
    let mut entry_of_client: Vec<Option<usize>> = vec![None; clients.len()];
    let idx_of_client: std::collections::HashMap<u32, usize> = clients
        .iter()
        .enumerate()
        .map(|(i, c)| (c.client_id, i))
        .collect();

    for set in &plan.sets {
        let shared_idx = stages.len();
        stages.push(StageState {
            frag: set.shared.frag,
            share: set.shared.alloc.share,
            max_batch: set.shared.alloc.batch,
            idle: set.shared.alloc.instances,
            queue: VecDeque::new(),
            next: None,
            in_service: vec![Vec::new(); set.shared.alloc.instances as usize],
        });
        for m in &set.members {
            let entry = match &m.align {
                Some(a) => {
                    let idx = stages.len();
                    stages.push(StageState {
                        frag: a.frag,
                        share: a.alloc.share,
                        max_batch: a.alloc.batch,
                        idle: a.alloc.instances,
                        queue: VecDeque::new(),
                        next: Some(shared_idx),
                        in_service: vec![
                            Vec::new();
                            a.alloc.instances as usize
                        ],
                    });
                    idx
                }
                None => shared_idx,
            };
            for c in &m.spec.clients {
                if let Some(&ci) = idx_of_client.get(&c.0) {
                    entry_of_client[ci] = Some(entry);
                }
            }
        }
    }

    let mut jobs: Vec<Job> = Vec::new();
    let mut events: BinaryHeap<Event> = BinaryHeap::new();
    for (ci, c) in clients.iter().enumerate() {
        if entry_of_client[ci].is_none() {
            continue;
        }
        for t_s in arrivals(
            c.rate_rps,
            opts.horizon_s,
            opts.process,
            opts.seed ^ (c.client_id as u64).wrapping_mul(0x9E3779B9),
        ) {
            let t_ms = t_s * 1e3 + c.upstream_ms;
            let job = jobs.len();
            jobs.push(Job {
                client: ci,
                server_arrival_ms: t_ms,
                accumulated_ms: 0.0,
            });
            events.push(Event {
                t_ms,
                kind: EventKind::Arrival {
                    stage: entry_of_client[ci].unwrap(),
                    job,
                },
            });
        }
    }

    let mut result = SimResult::default();
    let mut per_client: Vec<LatencyStats> =
        clients.iter().map(|_| LatencyStats::new()).collect();
    let bucket = |n: usize| -> u32 {
        let b = &cm.config().gpu.batch_buckets;
        b.iter().copied().find(|&x| x as usize >= n).unwrap_or(*b.last().unwrap())
    };

    while let Some(Event { t_ms, kind }) = events.pop() {
        match kind {
            EventKind::Arrival { stage, job } => {
                let st = &mut stages[stage];
                st.queue.push_back(job);
                if st.idle > 0 {
                    start_service(
                        cm, &mut stages, stage, t_ms, &mut events, &jobs,
                        &clients_budget(clients, &jobs), opts, &mut result,
                        bucket,
                    );
                }
            }
            EventKind::Depart { stage, instance } => {
                let exec_ms = {
                    let st = &stages[stage];
                    let n = st.in_service[instance].len();
                    cm.latency_ms(st.frag, bucket(n), st.share)
                };
                let batch = std::mem::take(
                    &mut stages[stage].in_service[instance],
                );
                let next = stages[stage].next;
                stages[stage].idle += 1;
                for job_id in batch {
                    jobs[job_id].accumulated_ms += exec_ms;
                    match next {
                        Some(ns) => {
                            stages[ns].queue.push_back(job_id);
                            if stages[ns].idle > 0 {
                                start_service(
                                    cm,
                                    &mut stages,
                                    ns,
                                    t_ms,
                                    &mut events,
                                    &jobs,
                                    &clients_budget(clients, &jobs),
                                    opts,
                                    &mut result,
                                    bucket,
                                );
                            }
                        }
                        None => {
                            let job = &jobs[job_id];
                            let c = &clients[job.client];
                            let server_ms =
                                t_ms - job.server_arrival_ms;
                            let e2e = c.upstream_ms + server_ms;
                            result.served += 1;
                            result.e2e.record(e2e);
                            per_client[job.client].record(e2e);
                        }
                    }
                }
                // the freed instance may immediately take queued work
                if !stages[stage].queue.is_empty() {
                    start_service(
                        cm, &mut stages, stage, t_ms, &mut events, &jobs,
                        &clients_budget(clients, &jobs), opts, &mut result,
                        bucket,
                    );
                }
            }
        }
    }

    result.per_client = clients
        .iter()
        .zip(per_client)
        .map(|(c, s)| (c.client_id, s))
        .collect();
    result.slo_attainment = {
        let mut ok = 0usize;
        let mut total = 0usize;
        for (c, s) in clients.iter().zip(result.per_client.iter()) {
            for &x in s.1.samples() {
                total += 1;
                if x <= c.slo_ms {
                    ok += 1;
                }
            }
        }
        if total == 0 {
            f64::NAN
        } else {
            ok as f64 / total as f64
        }
    };
    result
}

fn clients_budget<'a>(
    clients: &'a [SimClient],
    _jobs: &[Job],
) -> &'a [SimClient] {
    clients
}

#[allow(clippy::too_many_arguments)]
fn start_service(
    cm: &CostModel,
    stages: &mut [StageState],
    stage: usize,
    t_ms: f64,
    events: &mut BinaryHeap<Event>,
    jobs: &[Job],
    clients: &[SimClient],
    opts: &SimOptions,
    result: &mut SimResult,
    bucket: impl Fn(usize) -> u32,
) {
    let st = &mut stages[stage];
    if st.idle == 0 || st.queue.is_empty() {
        return;
    }
    // greedy batch; drop jobs that cannot meet their budget anymore
    let mut batch = Vec::new();
    while batch.len() < st.max_batch as usize {
        let Some(job_id) = st.queue.pop_front() else {
            break;
        };
        let job = &jobs[job_id];
        let elapsed = t_ms - job.server_arrival_ms;
        let probe =
            cm.latency_ms(st.frag, bucket(batch.len() + 1), st.share);
        let budget = clients[job.client].budget_ms;
        if opts.drop_on_slo && elapsed + job.accumulated_ms + probe > budget
        {
            result.dropped += 1;
            continue;
        }
        batch.push(job_id);
    }
    if batch.is_empty() {
        return;
    }
    let n = batch.len();
    let instance = st
        .in_service
        .iter()
        .position(Vec::is_empty)
        .expect("idle count says a slot is free");
    st.in_service[instance] = batch;
    st.idle -= 1;
    let exec_ms = cm.latency_ms(st.frag, bucket(n), st.share);
    events.push(Event {
        t_ms: t_ms + exec_ms,
        kind: EventKind::Depart { stage, instance },
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::repartition::{realign_group, RepartitionOptions};
    use crate::coordinator::{ClientId, FragmentSpec};

    fn setup() -> (CostModel, ExecutionPlan, Vec<SimClient>) {
        let cm = CostModel::new(Config::embedded());
        let mi = cm.model_index("inc").unwrap();
        let specs: Vec<FragmentSpec> = (0..4)
            .map(|i| {
                FragmentSpec::single(
                    ClientId(i),
                    mi,
                    2 + (i as usize % 2),
                    100.0,
                    30.0,
                )
            })
            .collect();
        let plan =
            realign_group(&cm, &specs, &RepartitionOptions::default());
        assert!(plan.infeasible.is_empty());
        let clients: Vec<SimClient> = (0..4)
            .map(|i| SimClient {
                client_id: i,
                upstream_ms: 40.0,
                slo_ms: 156.75,
                budget_ms: 100.0,
                rate_rps: 30.0,
            })
            .collect();
        (cm, plan, clients)
    }

    #[test]
    fn simulation_serves_most_requests_within_slo() {
        let (cm, plan, clients) = setup();
        let r = simulate(&cm, &plan, &clients, &SimOptions::default());
        let expected = (4.0 * 30.0 * 20.0) as usize;
        assert!(r.served + r.dropped > expected * 9 / 10);
        assert!(
            r.slo_attainment > 0.9,
            "attainment {} served {} dropped {}",
            r.slo_attainment,
            r.served,
            r.dropped
        );
        assert!(r.e2e.percentile(50.0) >= 40.0, "below upstream latency?");
    }

    #[test]
    fn deterministic_given_seed() {
        let (cm, plan, clients) = setup();
        let a = simulate(&cm, &plan, &clients, &SimOptions::default());
        let b = simulate(&cm, &plan, &clients, &SimOptions::default());
        assert_eq!(a.served, b.served);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.e2e.percentile(99.0), b.e2e.percentile(99.0));
    }

    #[test]
    fn underprovisioned_plan_queues_or_drops() {
        let (cm, mut plan, clients) = setup();
        // sabotage: strip the plan down to one instance with tiny share
        for set in &mut plan.sets {
            set.shared.alloc.instances = 1;
            set.shared.alloc.share = 5;
            set.shared.alloc.latency_ms =
                cm.latency_ms(set.shared.frag, set.shared.alloc.batch, 5);
            for m in &mut set.members {
                if let Some(a) = m.align.as_mut() {
                    a.alloc.instances = 1;
                    a.alloc.share = 5;
                }
            }
        }
        let r = simulate(&cm, &plan, &clients, &SimOptions::default());
        let healthy = simulate(
            &cm,
            &setup().1,
            &clients,
            &SimOptions::default(),
        );
        assert!(
            r.dropped > healthy.dropped,
            "sabotaged {} vs healthy {}",
            r.dropped,
            healthy.dropped
        );
    }

    #[test]
    fn unknown_clients_are_ignored() {
        let (cm, plan, mut clients) = setup();
        clients.push(SimClient {
            client_id: 999,
            upstream_ms: 1.0,
            slo_ms: 100.0,
            budget_ms: 50.0,
            rate_rps: 30.0,
        });
        let r = simulate(&cm, &plan, &clients, &SimOptions::default());
        assert!(r.per_client.iter().any(|(id, s)| *id == 999 && s.is_empty()));
    }
}
