//! Client simulator: one mobile device running hybrid DL over a 5G trace.
//!
//! Each second, the client observes its current uplink bandwidth, re-runs
//! Neurosurgeon, and (when the partition point or budget changes
//! materially) emits an updated `FragmentSpec` — the trigger that makes
//! Graft re-plan (paper §3 "trigger-based approach").

use super::mobile::DeviceKind;
use super::neurosurgeon::{choose_partition, PartitionDecision};
use super::trace::BandwidthTrace;
use crate::coordinator::fragment::{ClientId, FragmentSpec};
use crate::profiler::CostModel;

/// A simulated mobile client.
#[derive(Debug, Clone)]
pub struct ClientSim {
    pub id: ClientId,
    pub model: usize,
    pub device: DeviceKind,
    pub trace: BandwidthTrace,
    pub slo_ratio: f64,
    /// Restrict partition candidates (e.g. to the compiled point set for
    /// the real data path); `None` = all layers.
    pub candidates: Option<Vec<usize>>,
}

/// The client's state at a point in time.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub t_s: f64,
    pub mbps: f64,
    /// `None` when Neurosurgeon found no feasible split.
    pub spec: Option<FragmentSpec>,
    pub mobile_ms: f64,
    pub transfer_ms: f64,
    pub slo_ms: f64,
}

impl ClientSim {
    pub fn new(
        id: ClientId,
        model: usize,
        device: DeviceKind,
        trace: BandwidthTrace,
        slo_ratio: f64,
    ) -> Self {
        Self { id, model, device, trace, slo_ratio, candidates: None }
    }

    pub fn with_candidates(mut self, candidates: Vec<usize>) -> Self {
        self.candidates = Some(candidates);
        self
    }

    /// Evaluate the client at time `t_s` (seconds into its trace).
    pub fn state_at(&self, cm: &CostModel, t_s: f64) -> ClientState {
        let m = &cm.config().models[self.model];
        let mbps = self.trace.at(t_s);
        let slo_ms = self.device.slo_ms(m, self.slo_ratio);
        let decision = choose_partition(
            cm,
            self.model,
            self.device,
            mbps,
            slo_ms,
            self.candidates.as_deref(),
        );
        match decision {
            PartitionDecision::Hybrid(part) => ClientState {
                t_s,
                mbps,
                spec: Some(FragmentSpec::single(
                    self.id,
                    self.model,
                    part.p,
                    part.server_budget_ms,
                    m.rate_rps,
                )),
                mobile_ms: part.mobile_ms,
                transfer_ms: part.transfer_ms,
                slo_ms,
            },
            PartitionDecision::Infeasible => ClientState {
                t_s,
                mbps,
                spec: None,
                mobile_ms: 0.0,
                transfer_ms: 0.0,
                slo_ms,
            },
        }
    }

    /// Latency of running the whole model on-device (partition point =
    /// all layers, nothing uploaded) — the degraded-mode fallback when
    /// the server is unreachable (connection retries exhausted) or has
    /// lost its capacity.  Device-only execution needs no uplink, so
    /// the figure is bandwidth-independent.
    pub fn device_only_ms(&self, cm: &CostModel) -> f64 {
        let m = &cm.config().models[self.model];
        self.device.mobile_ms(m, m.layers)
    }

    /// Whether the device-only fallback still meets this client's SLO
    /// (weak devices on large models generally cannot — those clients
    /// can only wait out the server's recovery).
    pub fn device_fallback_feasible(&self, cm: &CostModel) -> bool {
        let m = &cm.config().models[self.model];
        self.device_only_ms(cm) <= self.device.slo_ms(m, self.slo_ratio)
    }

    /// The sequence of (time, spec) *changes* over the whole trace — the
    /// re-plan triggers. A change is a new partition point or a budget
    /// shift larger than `budget_tol_ms`.
    pub fn spec_changes(
        &self,
        cm: &CostModel,
        budget_tol_ms: f64,
    ) -> Vec<(f64, ClientState)> {
        let mut out: Vec<(f64, ClientState)> = Vec::new();
        for t in 0..self.trace.len_s() {
            let st = self.state_at(cm, t as f64);
            let changed = match (&out.last(), &st.spec) {
                (None, _) => true,
                (Some((_, prev)), cur) => match (&prev.spec, cur) {
                    (Some(a), Some(b)) => {
                        a.p != b.p
                            || (a.budget_ms - b.budget_ms).abs()
                                > budget_tol_ms
                    }
                    (None, None) => false,
                    _ => true,
                },
            };
            if changed {
                out.push((t as f64, st));
            }
        }
        out
    }
}

/// Build the paper's standard client fleets.
pub fn fleet(
    _cm: &CostModel,
    model: usize,
    nanos: usize,
    tx2s: usize,
    slo_ratio: f64,
    seed: u64,
) -> Vec<ClientSim> {
    use super::trace::TraceParams;
    let mut clients = Vec::new();
    for i in 0..nanos + tx2s {
        let device = if i < nanos { DeviceKind::Nano } else { DeviceKind::Tx2 };
        let trace = BandwidthTrace::generate(
            seed.wrapping_add(i as u64 * 7919),
            &TraceParams::default(),
        );
        clients.push(ClientSim::new(
            ClientId(i as u32),
            model,
            device,
            trace,
            slo_ratio,
        ));
    }
    clients
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn state_tracks_trace() {
        let cm = cm();
        let i = cm.model_index("inc").unwrap();
        let c = ClientSim::new(
            ClientId(0),
            i,
            DeviceKind::Nano,
            BandwidthTrace::embedded(),
            0.95,
        );
        let st = c.state_at(&cm, 0.0);
        assert_eq!(st.mbps, BandwidthTrace::embedded().mbps[0]);
        let spec = st.spec.expect("feasible at 210 Mbps");
        assert!(spec.budget_ms > 0.0);
        assert_eq!(spec.rate_rps, 30.0);
    }

    #[test]
    fn spec_changes_are_sparse_and_start_at_zero() {
        let cm = cm();
        let i = cm.model_index("inc").unwrap();
        let c = ClientSim::new(
            ClientId(0),
            i,
            DeviceKind::Nano,
            BandwidthTrace::embedded(),
            0.95,
        );
        let changes = c.spec_changes(&cm, 5.0);
        assert!(!changes.is_empty());
        assert_eq!(changes[0].0, 0.0);
        assert!(changes.len() < 50, "every second changed: {}", changes.len());
    }

    #[test]
    fn fleet_builds_mixed_devices() {
        let cm = cm();
        let i = cm.model_index("vgg").unwrap();
        let f = fleet(&cm, i, 4, 2, 0.95, 42);
        assert_eq!(f.len(), 6);
        assert_eq!(
            f.iter().filter(|c| c.device == DeviceKind::Nano).count(),
            4
        );
        // distinct traces per client
        assert_ne!(f[0].trace.mbps, f[1].trace.mbps);
    }

    #[test]
    fn device_only_fallback_is_bandwidth_independent() {
        let cm = cm();
        let i = cm.model_index("inc").unwrap();
        let c = ClientSim::new(
            ClientId(0),
            i,
            DeviceKind::Nano,
            BandwidthTrace::embedded(),
            0.95,
        );
        let full = c.device_only_ms(&cm);
        let m = &cm.config().models[i];
        assert!(full > 0.0);
        // full on-device = the device's total model latency
        assert!((full - c.device.mobile_ms(m, m.layers)).abs() < 1e-9);
        // always at least the cost of any hybrid split's mobile part
        let st = c.state_at(&cm, 0.0);
        assert!(full >= st.mobile_ms);
        // feasibility is exactly the SLO comparison
        assert_eq!(
            c.device_fallback_feasible(&cm),
            full <= c.device.slo_ms(m, 0.95)
        );
    }

    #[test]
    fn candidate_restriction_propagates() {
        let cm = cm();
        let i = cm.model_index("inc").unwrap();
        let pts: Vec<usize> =
            cm.config().models[i].common_starts.clone();
        let c = ClientSim::new(
            ClientId(0),
            i,
            DeviceKind::Nano,
            BandwidthTrace::embedded(),
            0.95,
        )
        .with_candidates(pts.clone());
        for t in 0..10 {
            if let Some(s) = c.state_at(&cm, t as f64).spec {
                assert!(pts.contains(&s.p));
            }
        }
    }
}
