//! Hybrid-DL substrate: everything that happens *before* requests reach
//! the edge server — mobile device execution models (Jetson Nano / TX2),
//! the 5G bandwidth trace driving network dynamics, the Neurosurgeon
//! partitioner choosing the split point, and the client simulator that
//! turns all of it into the stream of `FragmentSpec`s the Graft scheduler
//! consumes (paper §2.2, Fig 2).

mod client;
mod mobile;
mod neurosurgeon;
mod trace;

pub use client::{fleet, ClientSim, ClientState};
pub use mobile::DeviceKind;
pub use neurosurgeon::{choose_partition, transfer_ms, Partition, PartitionDecision};
pub use trace::{BandwidthTrace, TraceParams, EMBEDDED_5G_SNIPPET};
