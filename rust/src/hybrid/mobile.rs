//! Mobile device models (paper Table 1/2): per-layer execution latency of
//! each DNN on Jetson Nano (low-end) and TX2 (high-end), derived from the
//! calibrated full-model totals and the per-layer relative cost profile.

use crate::config::ModelSpec;

/// The two mobile device classes of the paper's testbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Jetson Nano (128-core Maxwell, 472 GFLOPS) — Table 1 row 1.
    Nano,
    /// Jetson TX2 (256-core Pascal, 1.33 TFLOPS) — Table 1 row 2.
    Tx2,
}

impl DeviceKind {
    /// Full-model mobile inference latency (ms) — Table 2.
    pub fn full_model_ms(&self, m: &ModelSpec) -> f64 {
        match self {
            DeviceKind::Nano => m.mobile_ms_nano,
            DeviceKind::Tx2 => m.mobile_ms_tx2,
        }
    }

    /// Latency of executing layers `1..=p` on the device (ms).
    pub fn mobile_ms(&self, m: &ModelSpec, p: usize) -> f64 {
        m.mobile_ms(self.full_model_ms(m), p)
    }

    /// Latency SLO of a model on this device: `slo_ratio` × the
    /// full-model mobile latency (paper §5.1 uses ratio 0.95).
    pub fn slo_ms(&self, m: &ModelSpec, slo_ratio: f64) -> f64 {
        self.full_model_ms(m) * slo_ratio
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Nano => "nano",
            DeviceKind::Tx2 => "tx2",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn table2_mobile_latencies() {
        let cfg = Config::embedded();
        for (name, nano, tx2) in [
            ("inc", 165.0, 94.0),
            ("res", 226.0, 114.0),
            ("vgg", 147.0, 77.0),
            ("mob", 84.0, 67.0),
            ("vit", 816.0, 603.0),
        ] {
            let m = cfg.model(name).unwrap();
            assert_eq!(DeviceKind::Nano.full_model_ms(m), nano);
            assert_eq!(DeviceKind::Tx2.full_model_ms(m), tx2);
            // partial execution is monotone and bounded by the total
            let mid = DeviceKind::Nano.mobile_ms(m, m.layers / 2);
            assert!(mid > 0.0 && mid < nano);
            assert!(
                (DeviceKind::Nano.mobile_ms(m, m.layers) - nano).abs() < 1e-9
            );
            assert_eq!(DeviceKind::Nano.mobile_ms(m, 0), 0.0);
        }
    }

    #[test]
    fn tx2_is_faster_than_nano() {
        let cfg = Config::embedded();
        for m in &cfg.models {
            for p in 1..=m.layers {
                assert!(
                    DeviceKind::Tx2.mobile_ms(m, p)
                        < DeviceKind::Nano.mobile_ms(m, p)
                );
            }
        }
    }

    #[test]
    fn slo_is_ratio_of_mobile_latency() {
        let cfg = Config::embedded();
        let m = cfg.model("inc").unwrap();
        assert!((DeviceKind::Nano.slo_ms(m, 0.95) - 156.75).abs() < 1e-9);
    }
}
