//! Neurosurgeon-style DNN partitioning (paper [23], §5.1).
//!
//! Given the current uplink bandwidth, the device's per-layer latency and
//! a server-side latency estimate, pick the partition point `p` that
//! minimises estimated end-to-end latency; hybrid DL then runs layers
//! `1..=p` on the device and `p+1..=L` on the server.  The paper notes
//! Neurosurgeon may fail to find a feasible point under tight SLOs
//! (§5.10) — we surface that as `PartitionDecision::Infeasible`.

use super::mobile::DeviceKind;
use crate::config::ModelSpec;
use crate::profiler::{CostModel, FragmentId};

/// Transfer latency (ms) of `kb` kilobytes over `mbps` megabits/s.
pub fn transfer_ms(kb: f64, mbps: f64) -> f64 {
    if mbps <= 0.0 {
        return f64::INFINITY;
    }
    kb * 8.0 / mbps // KB * 8 bit/B / (Mbit/s) == ms
}

/// A chosen split: layers `1..=p` on device, `p+1..=L` on server.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Partition {
    pub p: usize,
    /// Estimated end-to-end latency at decision time (ms).
    pub est_e2e_ms: f64,
    /// Mobile-side execution latency (ms).
    pub mobile_ms: f64,
    /// Uplink transfer latency of the activation (ms).
    pub transfer_ms: f64,
    /// Remaining server-side time budget: `slo - mobile - transfer` (ms).
    pub server_budget_ms: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionDecision {
    Hybrid(Partition),
    /// No candidate point meets the SLO at this bandwidth.
    Infeasible,
}

impl PartitionDecision {
    pub fn partition(&self) -> Option<Partition> {
        match self {
            PartitionDecision::Hybrid(p) => Some(*p),
            PartitionDecision::Infeasible => None,
        }
    }
}

/// Choose the partition point among `candidates` (`None` = all layers
/// 0..L-1; p = L, i.e. fully-local, is never a serving outcome and is
/// only reported as `Infeasible`-avoidance by callers that allow it).
///
/// The server-side estimate uses the reference profile (batch 1 at the
/// calibration share), exactly the coarse estimate Neurosurgeon has.
pub fn choose_partition(
    cm: &CostModel,
    model_idx: usize,
    device: DeviceKind,
    mbps: f64,
    slo_ms: f64,
    candidates: Option<&[usize]>,
) -> PartitionDecision {
    let m: &ModelSpec = &cm.config().models[model_idx];
    let all: Vec<usize> = (0..m.layers).collect();
    let candidates = candidates.unwrap_or(&all);

    let mut best: Option<Partition> = None;
    for &p in candidates {
        assert!(p < m.layers, "partition point must leave server work");
        let mobile = device.mobile_ms(m, p);
        let tx = transfer_ms(m.act_kb_at(p), mbps);
        let server = cm.latency_ms(
            FragmentId::new(model_idx, p, m.layers),
            1,
            cm.config().gpu.ref_share as u32,
        );
        let e2e = mobile + tx + server;
        let budget = slo_ms - mobile - tx;
        let cand = Partition {
            p,
            est_e2e_ms: e2e,
            mobile_ms: mobile,
            transfer_ms: tx,
            server_budget_ms: budget,
        };
        if e2e <= slo_ms
            && best.map_or(true, |b| e2e < b.est_e2e_ms)
        {
            best = Some(cand);
        }
    }
    match best {
        Some(p) => PartitionDecision::Hybrid(p),
        None => PartitionDecision::Infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cm() -> CostModel {
        CostModel::new(Config::embedded())
    }

    #[test]
    fn transfer_math() {
        assert!((transfer_ms(588.0, 100.0) - 47.04).abs() < 1e-9);
        assert!(transfer_ms(10.0, 0.0).is_infinite());
    }

    #[test]
    fn high_bandwidth_prefers_early_partition() {
        let cm = cm();
        let i = cm.model_index("inc").unwrap();
        let m = &cm.config().models[i];
        let slo = DeviceKind::Nano.slo_ms(m, 0.95);
        let hi = choose_partition(&cm, i, DeviceKind::Nano, 500.0, slo, None)
            .partition()
            .unwrap();
        let lo = choose_partition(&cm, i, DeviceKind::Nano, 60.0, slo, None)
            .partition()
            .unwrap();
        assert!(hi.p <= lo.p, "hi bw p={} lo bw p={}", hi.p, lo.p);
    }

    #[test]
    fn partition_budget_is_consistent() {
        let cm = cm();
        let i = cm.model_index("vgg").unwrap();
        let m = &cm.config().models[i];
        let slo = DeviceKind::Tx2.slo_ms(m, 0.95);
        let p = choose_partition(&cm, i, DeviceKind::Tx2, 200.0, slo, None)
            .partition()
            .unwrap();
        assert!(
            (p.server_budget_ms - (slo - p.mobile_ms - p.transfer_ms)).abs()
                < 1e-9
        );
        assert!(p.server_budget_ms > 0.0);
    }

    #[test]
    fn infeasible_under_tight_slo() {
        // paper §5.10: Neurosurgeon can fail below ratio ~0.7 for Inc
        let cm = cm();
        let i = cm.model_index("inc").unwrap();
        let m = &cm.config().models[i];
        let slo = DeviceKind::Nano.slo_ms(m, 0.1);
        assert_eq!(
            choose_partition(&cm, i, DeviceKind::Nano, 1.0, slo, None),
            PartitionDecision::Infeasible
        );
    }

    #[test]
    fn candidate_restriction_respected() {
        let cm = cm();
        let i = cm.model_index("inc").unwrap();
        let m = &cm.config().models[i];
        let slo = DeviceKind::Nano.slo_ms(m, 0.95);
        let cands = [2usize, 4];
        for bw in [60.0, 150.0, 400.0] {
            if let Some(p) =
                choose_partition(&cm, i, DeviceKind::Nano, bw, slo, Some(&cands))
                    .partition()
            {
                assert!(cands.contains(&p.p));
            }
        }
    }

    #[test]
    fn mob_polarises_at_layer_one() {
        // Mob's layer-1 activation is ~71% smaller than the input, so the
        // partitioner should consistently land on p=1 (paper §5.1).
        let cm = cm();
        let i = cm.model_index("mob").unwrap();
        let m = &cm.config().models[i];
        let slo = DeviceKind::Nano.slo_ms(m, 0.95);
        for bw in [80.0, 150.0, 300.0, 500.0] {
            let p = choose_partition(&cm, i, DeviceKind::Nano, bw, slo, None)
                .partition()
                .unwrap();
            assert_eq!(p.p, 1, "bw={bw}");
        }
    }
}
