//! 5G bandwidth traces (paper [55], Fig 2 bottom).
//!
//! The paper replays a real-world 5G dataset with `tc`.  We substitute a
//! seeded regime-switching random-walk generator spanning the same range
//! (tens to hundreds of Mbps with abrupt regime changes), plus an embedded
//! 50 s snippet shaped like the paper's Fig 2 excerpt so that `fig2` is
//! reproducible byte-for-byte.  Only `bandwidth(t)` ever reaches the rest
//! of the system, so this preserves the behaviour that matters: partition
//! point dynamics and time-budget variation.

use crate::util::Rng;

/// The Fig-2-like 50 s snippet (uplink Mbps at 1 Hz; 5G uplink is far
/// below downlink, tens of Mbps with deep fades).
pub const EMBEDDED_5G_SNIPPET: [f64; 50] = [
    84.0, 90.0, 96.0, 92.0, 82.0, 73.0, 64.0, 59.0, 62.0, 68.0,
    78.0, 92.0, 112.0, 133.0, 148.0, 161.0, 155.0, 140.0, 124.0, 109.0,
    96.0, 87.0, 74.0, 57.0, 42.0, 34.0, 29.0, 26.0, 31.0, 38.0,
    48.0, 62.0, 76.0, 90.0, 102.0, 116.0, 129.0, 142.0, 160.0, 177.0,
    188.0, 181.0, 168.0, 155.0, 138.0, 121.0, 106.0, 95.0, 86.0, 79.0,
];

/// Parameters for the synthetic 5G generator.
#[derive(Debug, Clone)]
pub struct TraceParams {
    pub min_mbps: f64,
    pub max_mbps: f64,
    /// Per-step relative drift std-dev within a regime.
    pub walk_sigma: f64,
    /// Probability per step of switching regime (handover / blockage).
    pub regime_switch_p: f64,
    pub len_s: usize,
}

impl Default for TraceParams {
    fn default() -> Self {
        Self {
            min_mbps: 20.0,
            max_mbps: 220.0,
            walk_sigma: 0.08,
            regime_switch_p: 0.04,
            len_s: 300,
        }
    }
}

/// A bandwidth trace sampled at 1 Hz.
#[derive(Debug, Clone)]
pub struct BandwidthTrace {
    pub mbps: Vec<f64>,
}

impl BandwidthTrace {
    /// The embedded Fig-2 snippet.
    pub fn embedded() -> Self {
        Self { mbps: EMBEDDED_5G_SNIPPET.to_vec() }
    }

    /// Deterministic synthetic trace from a seed.
    pub fn generate(seed: u64, params: &TraceParams) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut mbps = Vec::with_capacity(params.len_s);
        let mut regime_mid = rng.range(params.min_mbps, params.max_mbps);
        let mut bw = regime_mid;
        for _ in 0..params.len_s {
            if rng.f64() < params.regime_switch_p {
                regime_mid = rng.range(params.min_mbps, params.max_mbps);
            }
            // mean-revert towards the regime midpoint + multiplicative noise
            let noise: f64 =
                1.0 + params.walk_sigma * (rng.f64() * 2.0 - 1.0);
            bw = (0.7 * bw + 0.3 * regime_mid) * noise;
            bw = bw.clamp(params.min_mbps, params.max_mbps);
            mbps.push(bw);
        }
        Self { mbps }
    }

    /// Bandwidth at second `t` (clamps to the trace ends, cycles if empty
    /// is impossible — traces are non-empty by construction).
    pub fn at(&self, t_s: f64) -> f64 {
        let i = (t_s.max(0.0) as usize).min(self.mbps.len() - 1);
        self.mbps[i]
    }

    /// Mean bandwidth — what the Static baselines provision for (§5.1).
    pub fn mean(&self) -> f64 {
        self.mbps.iter().sum::<f64>() / self.mbps.len() as f64
    }

    pub fn len_s(&self) -> usize {
        self.mbps.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedded_snippet_is_50s_in_range() {
        let t = BandwidthTrace::embedded();
        assert_eq!(t.len_s(), 50);
        assert!(t.mbps.iter().all(|&b| (20.0..=250.0).contains(&b)));
    }

    #[test]
    fn generator_is_deterministic_and_bounded() {
        let p = TraceParams::default();
        let a = BandwidthTrace::generate(7, &p);
        let b = BandwidthTrace::generate(7, &p);
        assert_eq!(a.mbps, b.mbps);
        assert!(a
            .mbps
            .iter()
            .all(|&x| (p.min_mbps..=p.max_mbps).contains(&x)));
        let c = BandwidthTrace::generate(8, &p);
        assert_ne!(a.mbps, c.mbps);
    }

    #[test]
    fn generator_actually_varies() {
        let t = BandwidthTrace::generate(1, &TraceParams::default());
        let mean = t.mean();
        let var = t.mbps.iter().map(|b| (b - mean).powi(2)).sum::<f64>()
            / t.mbps.len() as f64;
        assert!(var.sqrt() > 10.0, "std {} too small", var.sqrt());
    }

    #[test]
    fn at_clamps_to_ends() {
        let t = BandwidthTrace::embedded();
        assert_eq!(t.at(-5.0), t.mbps[0]);
        assert_eq!(t.at(1e9), *t.mbps.last().unwrap());
    }
}
