//! Offline in-tree substitute for the `anyhow` crate.
//!
//! The container build has no network and no registry, so graft vendors
//! the small subset of anyhow it actually uses: the [`Error`] type with
//! context chaining, the [`Result`] alias with a defaulted error type,
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension
//! trait on `Result` and `Option`.  The structure (notably the private
//! `ext::StdError` dispatch trait) mirrors the real crate so the two are
//! drop-in interchangeable.

use std::fmt::{self, Display};

/// An error with an optional chain of context messages.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message plus nested causes, colon-joined
    /// (what `{:#}` prints).
    fn chain_string(&self) -> String {
        let mut out = self.msg.clone();
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            out.push_str(": ");
            out.push_str(&e.msg);
            cur = e.source.as_deref();
        }
        out
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain_string())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes the blanket `From` below (and
// the dual `ext::StdError` impls) coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg, source: None }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

mod ext {
    use super::{Display, Error};

    /// Private dispatch: turn either a std error or an [`Error`] into an
    /// [`Error`] with added context (same trick as the real anyhow).
    pub trait StdError {
        fn ext_context<C: Display + Send + Sync + 'static>(
            self,
            context: C,
        ) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display + Send + Sync + 'static>(
            self,
            context: C,
        ) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display + Send + Sync + 'static>(
            self,
            context: C,
        ) -> Error {
            self.context(context)
        }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C: Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(
        self,
        context: C,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/here")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(e.to_string(), "value 3 bad");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");
        fn f() -> Result<()> {
            bail!("boom {}", 7)
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn option_context_and_question_mark() {
        fn g() -> Result<u32> {
            let v: Option<u32> = None;
            let v = v.context("missing value")?;
            Ok(v)
        }
        assert_eq!(g().unwrap_err().to_string(), "missing value");

        fn h() -> Result<u32> {
            let n: u32 = "zzz".parse()?; // ParseIntError via blanket From
            Ok(n)
        }
        assert!(h().is_err());
    }

    #[test]
    fn with_context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("inner failure")
        }
        let e = inner().with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: inner failure");
    }
}
