//! Offline in-tree substitute for the `libc` crate: only the signal
//! bindings graft's CLI uses (ignoring `SIGPIPE` so `graft ... | head`
//! dies quietly).  Values match Linux; on non-Linux targets the constant
//! differs but the call remains harmless.

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type sighandler_t = usize;

pub const SIGPIPE: c_int = 13;
pub const SIG_DFL: sighandler_t = 0;

extern "C" {
    /// POSIX `signal(2)`.
    pub fn signal(signum: c_int, handler: sighandler_t) -> sighandler_t;
}

#[cfg(test)]
mod tests {
    #[test]
    fn set_default_pipe_handler() {
        // installing the default handler is a no-op semantically
        unsafe {
            super::signal(super::SIGPIPE, super::SIG_DFL);
        }
    }
}
