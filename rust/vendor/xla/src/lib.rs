//! Offline compile-time stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links the XLA C library, which the offline container
//! cannot build.  This stub reproduces exactly the API surface
//! `graft::runtime::Engine` touches, so the crate compiles everywhere;
//! `PjRtClient::cpu()` fails at runtime with a clear message, which the
//! engine surfaces as an `Engine::new` error.  Tests and benches that
//! need PJRT already skip when `artifacts/manifest.json` is absent, so
//! no test path reaches the stub.  Swapping this path dependency for the
//! real `xla` crate re-enables the hardware execution path unchanged.

use std::fmt;
use std::path::Path;

/// Stub error; formatted into anyhow errors by the engine via `{:?}`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT backend unavailable: graft was built against the offline \
         xla stub (swap rust/vendor/xla for the real xla crate)"
            .to_string(),
    ))
}

#[derive(Debug)]
pub struct PjRtClient;

#[derive(Debug)]
pub struct PjRtDevice;

#[derive(Debug)]
pub struct PjRtBuffer;

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

#[derive(Debug)]
pub struct HloModuleProto;

#[derive(Debug)]
pub struct XlaComputation;

#[derive(Debug)]
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_at_construction() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("unavailable"));
    }
}
