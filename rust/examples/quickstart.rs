//! Quickstart: the Graft public API in ~60 lines.
//!
//! 1. load the canonical config and cost model;
//! 2. describe a handful of hybrid-DL fragment demands;
//! 3. run the Graft scheduler (merge → group → re-partition);
//! 4. inspect the plan and compare against GSLICE;
//! 5. (if `make artifacts` has run) execute one fragment on PJRT.
//!
//!   cargo run --release --example quickstart

use graft::config::Config;
use graft::coordinator::baselines::gslice;
use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::coordinator::{ClientId, FragmentSpec};
use graft::profiler::{AllocConstraints, CostModel};
use graft::runtime::{default_artifacts_dir, Engine};

fn main() -> anyhow::Result<()> {
    // 1. configuration + analytical cost model (calibrated to Table 2)
    let cm = CostModel::new(Config::embedded());
    let inc = cm.model_index("inc").unwrap();

    // 2. five Inception clients with misaligned partition points — the
    //    exact situation of the paper's Fig 1/Fig 3
    let demands: Vec<FragmentSpec> = [
        (0u32, 2usize, 110.0),
        (1, 2, 95.0),
        (2, 3, 100.0),
        (3, 4, 120.0),
        (4, 5, 90.0),
    ]
    .iter()
    .map(|&(id, p, budget_ms)| {
        FragmentSpec::single(ClientId(id), inc, p, budget_ms, 30.0)
    })
    .collect();

    // 3. Graft plan
    let scheduler = Scheduler::new(cm.clone(), SchedulerOptions::default());
    let (plan, stats) = scheduler.plan(&demands);
    println!(
        "Graft: {} demands -> {} re-aligned sets in {:.2} ms",
        demands.len(),
        plan.sets.len(),
        stats.total_ms
    );
    for set in &plan.sets {
        println!(
            "  re-partition@{:<2} members={} shared: batch={} share={}% x{}",
            set.point,
            set.members.len(),
            set.shared.alloc.batch,
            set.shared.alloc.share,
            set.shared.alloc.instances
        );
    }

    // 4. compare with GSLICE (no re-alignment)
    let baseline = gslice(&cm, &demands, &AllocConstraints::default());
    println!(
        "total GPU share: graft={}%  gslice={}%  (saving {:.0}%)",
        plan.total_share(),
        baseline.total_share(),
        100.0
            * (1.0
                - plan.total_share() as f64
                    / baseline.total_share() as f64)
    );

    // 5. run a real fragment if the AOT artifacts are present
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let engine = Engine::new(&dir)?;
        let dims = engine.manifest().models["inc"].dims.clone();
        let x: Vec<Vec<f32>> = vec![vec![0.1; dims[2]]; 2];
        let out = engine.run("inc", 2, dims.len() - 1, &x)?;
        println!(
            "PJRT: executed inc fragment [2..{}] on batch 2 -> {} logits/row",
            dims.len() - 1,
            out.dim_out
        );
    } else {
        println!("(run `make artifacts` to enable the PJRT demo step)");
    }
    Ok(())
}
