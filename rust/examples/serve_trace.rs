//! End-to-end driver (DESIGN.md §6): replay a 5G trace for a small fleet
//! of hybrid-DL clients against the *real* serving stack — TCP ingress,
//! Graft scheduling, batch queues, and PJRT execution of the AOT
//! artifacts — and report latency/throughput/SLO attainment.
//!
//! The run proceeds in epochs: at each epoch boundary every client
//! re-partitions against its current bandwidth (Neurosurgeon restricted
//! to the compiled point set), Graft re-plans, and the executor is
//! re-deployed (the paper's trigger-based re-planning; outdated
//! instances terminate at the swap).
//!
//! On machines with few cores, real-time pacing is noisy (scheduling
//! delays rival the modeled GPU latencies); `TIME_SCALE` runs the whole
//! data path in slowed virtual time — arrivals, pacing and budgets all
//! scale together and every reported number is in *modeled* (GPU-time)
//! milliseconds, so results are machine-independent.
//!
//!   cargo run --release --example serve_trace -- [model] [clients] [epochs] [epoch_s] [time_scale]

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use graft::config::Config;
use graft::coordinator::repartition::RepartitionOptions;
use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::experiments::common::{fleet, Scale};
use graft::hybrid::ClientSim;
use graft::metrics::LatencyStats;
use graft::profiler::CostModel;
use graft::runtime::{default_artifacts_dir, Engine};
use graft::serving::{
    ExecutorMode, Request, Server, ServerOptions, TcpClient, TcpFront,
};
use graft::util::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("vgg").to_string();
    let n_clients: usize =
        args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let epochs: usize =
        args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(3);
    let epoch_s: f64 =
        args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(5.0);
    // wall milliseconds per modeled GPU millisecond; sized for 1-core CI
    let time_scale: f64 =
        args.get(4).map(|s| s.parse()).transpose()?.unwrap_or(6.0);

    let cm = CostModel::new(Config::embedded());
    let mi = cm.model_index(&model).expect("known model");
    let spec = &cm.config().models[mi];
    let engine = Arc::new(Engine::new(&default_artifacts_dir())?);

    // clients restricted to compiled partition points (p < layers)
    let points = spec.points();
    let clients: Vec<ClientSim> = fleet(
        &cm,
        mi,
        Scale::SmallHeter,
        cm.config().slo_ratio_default,
        7,
    )
    .into_iter()
    .take(n_clients)
    .map(|c| c.with_candidates(points[..points.len() - 1].to_vec()))
    .collect();

    println!(
        "serve_trace: model={model} clients={n_clients} epochs={epochs} \
         epoch={epoch_s}s rate={} RPS/client",
        spec.rate_rps
    );

    let mut all = LatencyStats::new();
    let mut total_sent = 0u64;
    let mut total_served = 0u64;
    let mut total_dropped = 0u64;
    let mut slo_ok = 0u64;
    let mut total_batches = 0u64;
    let mut total_batched_reqs = 0u64;
    let wall0 = Instant::now();

    for epoch in 0..epochs {
        let t_trace = epoch as f64 * epoch_s;
        // 1. snapshot demands; re-plan (the trigger-based re-schedule)
        let mut specs = Vec::new();
        let mut states = Vec::new();
        for c in &clients {
            let st = c.state_at(&cm, t_trace);
            if let Some(s) = st.spec.clone() {
                specs.push(s);
            }
            states.push(st);
        }
        let sched = Scheduler::new(
            cm.clone(),
            SchedulerOptions {
                repartition: RepartitionOptions {
                    point_set: Some(points.clone()),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        let (plan, stats) = sched.plan(&specs);
        println!(
            "epoch {epoch}: {} demands -> {} sets, {}% share, planned in {:.1} ms",
            specs.len(),
            plan.sets.len(),
            plan.total_share(),
            stats.total_ms
        );

        // 2. deploy (warm up the PJRT executables first: lazy compilation
        //    takes ~1 s per fragment and would pollute the epoch's tail)
        let frags: Vec<(String, usize, usize)> = plan
            .sets
            .iter()
            .flat_map(|set| {
                let name = cm.config().models[set.model].name.clone();
                let mut v = vec![(
                    name.clone(),
                    set.shared.frag.start,
                    set.shared.frag.end,
                )];
                v.extend(set.members.iter().filter_map(|m| {
                    m.align
                        .as_ref()
                        .map(|a| (name.clone(), a.frag.start, a.frag.end))
                }));
                v
            })
            .collect();
        let n_warm = engine.warmup(&frags)?;
        println!("  warmed {n_warm} executables");
        let server = Arc::new(Server::start(
            engine.clone(),
            &cm,
            &plan,
            ServerOptions {
                time_scale,
                drop_on_slo: true,
                mode: ExecutorMode::Pool,
                ..Default::default()
            },
        ));
        let front = TcpFront::start("127.0.0.1:0", server.clone())?;
        let addr = front.addr;

        // 3. drive the clients for one epoch (threads; real TCP loopback)
        let mut handles = Vec::new();
        for (ci, c) in clients.iter().enumerate() {
            let st = states[ci].clone();
            let Some(cspec) = st.spec.clone() else { continue };
            let dims = cm.config().models[mi].dims.clone();
            let rate = spec.rate_rps / time_scale; // virtual-time arrivals
            let slo_ms = st.slo_ms;
            let client_id = c.id.0;
            let epoch_wall_s = epoch_s * time_scale;
            handles.push(std::thread::spawn(move || {
                let tcp = TcpClient::connect(addr).expect("connect");
                let mut tcp_w = tcp.try_clone().expect("clone");
                let (rtx, rrx) = mpsc::channel();
                let reader = std::thread::spawn(move || {
                    let mut tcp_r = tcp;
                    while let Ok(resp) = tcp_r.recv() {
                        if rtx.send(resp).is_err() {
                            break;
                        }
                    }
                });
                let mut rng = Rng::seed_from_u64(1000 + client_id as u64);
                let gap = Duration::from_secs_f64(1.0 / rate);
                let start = Instant::now();
                let mut sent = 0u64;
                let mut seq = 0u32;
                while start.elapsed().as_secs_f64() < epoch_wall_s {
                    let payload: Vec<f32> = (0..dims[cspec.p])
                        .map(|_| rng.normal() as f32)
                        .collect();
                    tcp_w
                        .send(&Request {
                            client_id,
                            model: 0,
                            p: cspec.p as u16,
                            seq,
                            t_capture_ms: 0.0,
                            upstream_ms: st.mobile_ms + st.transfer_ms,
                            budget_ms: cspec.budget_ms,
                            payload,
                        })
                        .expect("send");
                    sent += 1;
                    seq += 1;
                    std::thread::sleep(gap);
                }
                // grace period for in-flight responses, then hang up
                // (explicit shutdown: the reader clone keeps the fd open)
                std::thread::sleep(Duration::from_millis(400));
                tcp_w.shutdown();
                drop(tcp_w);
                let mut lat = LatencyStats::new();
                let mut served = 0u64;
                let mut dropped = 0u64;
                let mut ok = 0u64;
                for resp in rrx.try_iter() {
                    if resp.dropped {
                        dropped += 1;
                    } else {
                        served += 1;
                        lat.record(resp.e2e_ms);
                        if resp.e2e_ms <= slo_ms {
                            ok += 1;
                        }
                    }
                }
                drop(reader); // detached; socket closes when tcp_r errors
                (sent, served, dropped, ok, lat)
            }));
        }
        for h in handles {
            let (sent, served, dropped, ok, lat) = h.join().unwrap();
            total_sent += sent;
            total_served += served;
            total_dropped += dropped;
            slo_ok += ok;
            all.merge(&lat);
        }
        use std::sync::atomic::Ordering;
        total_batches += server.counters.batches.load(Ordering::Relaxed);
        total_batched_reqs +=
            server.counters.batched_requests.load(Ordering::Relaxed);
        println!(
            "  epoch {epoch}: served={} dropped={} budget_violations={}",
            server.counters.served.load(Ordering::Relaxed),
            server.counters.dropped.load(Ordering::Relaxed),
            server.counters.budget_violations.load(Ordering::Relaxed)
        );
        front.stop();
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }

    let wall = wall0.elapsed().as_secs_f64();
    let virt = wall / time_scale;
    println!("\n=== serve_trace summary ({model}) ===");
    println!(
        "wall time           : {wall:.1} s ({virt:.1} virtual s at x{time_scale})"
    );
    println!("requests sent       : {total_sent}");
    println!(
        "served / dropped    : {total_served} / {total_dropped} ({:.1}% dropped)",
        100.0 * total_dropped as f64 / total_sent.max(1) as f64
    );
    println!(
        "throughput          : {:.1} req/s served (virtual time)",
        total_served as f64 / virt
    );
    println!(
        "mean batch size     : {:.2}",
        total_batched_reqs as f64 / total_batches.max(1) as f64
    );
    if !all.is_empty() {
        println!(
            "e2e latency (ms)    : p50 {:.1}  p95 {:.1}  p99 {:.1}  mean {:.1}",
            all.percentile(50.0),
            all.percentile(95.0),
            all.percentile(99.0),
            all.mean()
        );
        println!(
            "SLO attainment      : {:.1}% of served",
            100.0 * slo_ok as f64 / total_served.max(1) as f64
        );
    }
    Ok(())
}
