//! Massive-scale simulation (paper §5.8): thousands of DNN fragments,
//! far beyond what a single testbed GPU could host.  Compares the total
//! GPU share allocated by Graft (merging threshold 0.01 as in the
//! paper), GSLICE, GSLICE⁺ and Static, and reports scheduler wall time.
//!
//!   cargo run --release --example massive_scale -- [n_fragments] [model]

use std::time::Instant;

use graft::config::Config;
use graft::coordinator::baselines::{gslice, gslice_plus};
use graft::coordinator::merging::MergeOptions;
use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::experiments::common::random_fragments;
use graft::profiler::{AllocConstraints, CostModel};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args
        .first()
        .map(|s| s.parse().expect("n_fragments"))
        .unwrap_or(2000);
    let model = args.get(1).map(String::as_str).unwrap_or("inc");

    let cm = CostModel::new(Config::embedded());
    let mi = cm.model_index(model).expect("known model");
    let frags = random_fragments(&cm, mi, n, 0xBEEF);
    let cons = AllocConstraints::default();
    println!("massive_scale: {n} random {model} fragments\n");
    println!(
        "{:<10} {:>12} {:>8} {:>10} {:>10}",
        "system", "share_total", "gpus", "sets", "time_ms"
    );

    // Graft (merging threshold 0.01 per §5.8)
    let sched = Scheduler::new(
        cm.clone(),
        SchedulerOptions {
            merge: MergeOptions { threshold: 0.01, ..Default::default() },
            pool_size: 4,
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    let (plan, stats) = sched.plan(&frags);
    let graft_ms = t0.elapsed().as_secs_f64() * 1e3;
    // the scheduler stamps its own FFD placement (feedback-tightened
    // when packing fragments badly); baselines below are packed post-hoc
    let gpus = plan
        .placed_gpus()
        .map_or("nan".to_string(), |g| g.to_string());
    println!(
        "{:<10} {:>12} {:>8} {:>10} {:>10.1}",
        "graft",
        plan.total_share(),
        gpus,
        plan.sets.len(),
        graft_ms
    );
    println!(
        "  (merge {} -> {} fragments in {:.1} ms; {} groups; \
         fragmentation {:.1}%, {} feedback rounds)",
        stats.n_input,
        stats.n_after_merge,
        stats.merge_ms,
        stats.n_groups,
        stats.fragmentation * 100.0,
        stats.placement_rounds
    );

    type Baseline = fn(
        &CostModel,
        &[graft::coordinator::FragmentSpec],
        &AllocConstraints,
    ) -> graft::coordinator::ExecutionPlan;
    let baselines: [(&str, Baseline); 2] =
        [("gslice", gslice), ("gslice+", gslice_plus)];
    for (name, build) in baselines {
        let t = Instant::now();
        let p = build(&cm, &frags, &cons);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        println!(
            "{:<10} {:>12} {:>8} {:>10} {:>10.1}",
            name,
            p.total_share(),
            // unstamped baseline: gpus() runs a fresh FFD placement
            // ("nan" = some instance cannot fit a single GPU)
            p.gpus(&cm).map_or("nan".to_string(), |g| g.to_string()),
            p.sets.len(),
            ms
        );
    }
    println!(
        "\nGraft vs GSLICE: {:.1}% less GPU share",
        100.0
            * (1.0
                - plan.total_share() as f64
                    / gslice(&cm, &frags, &cons).total_share() as f64)
    );
}
