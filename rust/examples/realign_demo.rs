//! Re-alignment walkthrough (the paper's Fig 3): take five misaligned
//! VGG fragments, show the provisioning without re-partitioning, then
//! the Graft re-alignment — alignment stages + one shared batched
//! suffix — and the resource delta, stage by stage.
//!
//!   cargo run --release --example realign_demo

use graft::config::Config;
use graft::coordinator::repartition::{
    no_realign_plan, realign_group, plan_is_slo_safe, RepartitionOptions,
};
use graft::coordinator::{ClientId, FragmentSpec};
use graft::profiler::{AllocConstraints, CostModel};

fn main() {
    let cm = CostModel::new(Config::embedded());
    let inc = cm.model_index("inc").unwrap();
    let layers = cm.config().models[inc].layers;

    let frags: Vec<FragmentSpec> = [
        (0u32, 1usize, 95.0),
        (1, 2, 102.0),
        (2, 2, 98.0),
        (3, 3, 110.0),
        (4, 4, 120.0),
    ]
    .iter()
    .map(|&(id, p, t)| FragmentSpec::single(ClientId(id), inc, p, t, 30.0))
    .collect();

    println!("five misaligned Inception-v3 fragments (server side, L={layers}):");
    for f in &frags {
        println!(
            "  client {:?}: layers {}..{}  budget {:>5.1} ms  {} RPS",
            f.clients[0], f.p, layers, f.budget_ms, f.rate_rps
        );
    }

    let cons = AllocConstraints::default();
    let without = no_realign_plan(&cm, &frags, &cons);
    println!("\n-- without re-partitioning (per-fragment provisioning) --");
    for set in &without.sets {
        let a = &set.shared.alloc;
        println!(
            "  [{}..{}] batch={} share={}% x{} inst  (lat {:.1} ms, {:.0} RPS)",
            set.point, layers, a.batch, a.share, a.instances,
            a.latency_ms, a.throughput_rps
        );
    }
    println!("  total: {}%", without.total_share());

    let with = realign_group(&cm, &frags, &RepartitionOptions::default());
    println!("\n-- Graft re-alignment --");
    for set in &with.sets {
        println!("  set re-partitioned at layer {}:", set.point);
        for m in &set.members {
            match &m.align {
                Some(a) => println!(
                    "    align  [{}..{}] batch={} share={}% x{}",
                    m.spec.p,
                    set.point,
                    a.alloc.batch,
                    a.alloc.share,
                    a.alloc.instances
                ),
                None => println!(
                    "    member p={} enters the shared stage directly",
                    m.spec.p
                ),
            }
        }
        let s = &set.shared.alloc;
        println!(
            "    shared [{}..{}] batch={} share={}% x{}  <- batches {:.0} RPS from {} clients",
            set.point,
            layers,
            s.batch,
            s.share,
            s.instances,
            set.shared.demand_rps,
            set.members.len()
        );
    }
    println!("  total: {}%", with.total_share());
    assert!(plan_is_slo_safe(&with));

    println!(
        "\nre-alignment saves {:.0}% GPU share ({}% -> {}%), SLO-safe",
        100.0 * (1.0 - with.total_share() as f64 / without.total_share() as f64),
        without.total_share(),
        with.total_share()
    );
}
