//! Serving data-path benches: wire protocol, batch queue, PJRT fragment
//! execution (needs `make artifacts`), and the in-process serving loop.
//!
//!   cargo bench --bench serving

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};

use graft::config::Config;
use graft::coordinator::repartition::{realign_group, RepartitionOptions};
use graft::coordinator::{ClientId, FragmentSpec};
use graft::profiler::CostModel;
use graft::experiments::scale::serve_synthetic;
use graft::serving::{
    BatchQueue, ExecutorMode, MockExecutor, Request, Server, ServerOptions,
    ShardedBatchQueue, WorkItem,
};
use graft::util::bench::{bench, run_group};
use graft::util::Rng;

fn main() {
    let cm = CostModel::new(Config::embedded());
    let mi = cm.model_index("vgg").unwrap();
    let dims = cm.config().models[mi].dims.clone();

    // wire protocol
    let mut rng = Rng::seed_from_u64(3);
    let req = Request {
        client_id: 1,
        model: mi as u16,
        p: 1,
        seq: 9,
        t_capture_ms: 0.0,
        upstream_ms: 50.0,
        budget_ms: 80.0,
        payload: (0..dims[1]).map(|_| rng.normal() as f32).collect(),
    };
    let encoded = req.encode();
    run_group(
        "protocol",
        vec![
            bench("request encode (512-wide payload)", || req.encode()),
            bench("request decode", || Request::decode(&encoded).unwrap()),
        ],
    );

    // batch queue (single-lock reference vs per-instance shards)
    let item = |i: u32| WorkItem {
        payload: vec![0.0; 8],
        server_arrival: std::time::Instant::now(),
        budget_ms: 100.0,
        accumulated_ms: 0.0,
        ctx: i,
    };
    run_group(
        "batch queue",
        vec![
            bench("single: push+pop batch of 8", || {
                let q: BatchQueue<u32> = BatchQueue::new();
                for i in 0..8 {
                    q.push(item(i));
                }
                q.pop_batch(8).unwrap().len()
            }),
            bench("sharded(8): push+pop batch of 8", || {
                let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(8);
                for i in 0..8 {
                    q.push(item(i));
                }
                q.try_pop_batch(0, 8).len()
            }),
            bench("sharded(8): 64 push + steal-pop x8", || {
                let q: ShardedBatchQueue<u32> = ShardedBatchQueue::new(8);
                for i in 0..64 {
                    q.push(item(i));
                }
                let mut n = 0;
                for home in 0..8 {
                    n += q.try_pop_batch(home, 8).len();
                }
                n
            }),
        ],
    );

    // in-process serving loop with the mock executor (no pacing)
    let specs = vec![
        FragmentSpec::single(ClientId(0), mi, 1, 90.0, 30.0),
        FragmentSpec::single(ClientId(1), mi, 2, 80.0, 30.0),
    ];
    let plan = realign_group(&cm, &specs, &RepartitionOptions::default());
    let dims_map: HashMap<String, Vec<usize>> = cm
        .config()
        .models
        .iter()
        .map(|m| (m.name.clone(), m.dims.clone()))
        .collect();
    let server = Server::start(
        Arc::new(MockExecutor { dims: dims_map }),
        &cm,
        &plan,
        ServerOptions {
            time_scale: 0.0,
            drop_on_slo: false,
            mode: ExecutorMode::Pool,
            ..Default::default()
        },
    );
    let payload: Vec<f32> = vec![0.5; dims[1]];
    run_group(
        "serving loop (mock executor)",
        vec![bench("submit -> response", || {
            let (tx, rx) = mpsc::channel();
            server.submit(
                Request {
                    client_id: 0,
                    model: mi as u16,
                    p: 1,
                    seq: 0,
                    t_capture_ms: 0.0,
                    upstream_ms: 0.0,
                    budget_ms: 1e9,
                    payload: payload.clone(),
                },
                tx,
            );
            rx.recv().unwrap()
        })],
    );
    server.shutdown();

    // executor cores head-to-head on the same small plan (2k requests,
    // mock executor, no pacing)
    run_group(
        "executor (2k reqs end-to-end)",
        vec![
            bench_serving_mode(&cm, &plan, ExecutorMode::Threads),
            bench_serving_mode(&cm, &plan, ExecutorMode::Pool),
        ],
    );

    // real PJRT execution (skipped without artifacts)
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = graft::runtime::Engine::new(&dir).unwrap();
        let _ = engine.run("vgg", 0, 6, &[vec![0.1; dims[0]]]); // compile
        let rows1 = vec![vec![0.1f32; dims[0]]];
        let rows8: Vec<Vec<f32>> = vec![vec![0.1; dims[0]]; 8];
        run_group(
            "PJRT fragment execution (vgg 0..6)",
            vec![
                bench("batch 1", || engine.run("vgg", 0, 6, &rows1).unwrap()),
                bench("batch 8", || engine.run("vgg", 0, 6, &rows8).unwrap()),
            ],
        );
    } else {
        println!("(artifacts missing; PJRT benches skipped)");
    }
}

/// Time `serve_synthetic` (2k synthetic requests, mock executor, no
/// pacing) under one executor mode.
fn bench_serving_mode(
    cm: &CostModel,
    plan: &graft::coordinator::ExecutionPlan,
    mode: ExecutorMode,
) -> graft::util::bench::BenchResult {
    graft::util::bench::bench_with(
        &format!("{mode:?} executor"),
        0,
        2,
        std::time::Duration::from_millis(1),
        &mut || serve_synthetic(cm, plan, mode, 2000).requests,
    )
}
