//! End-to-end experiment benches: the time to regenerate each paper
//! table/figure family (useful to track harness regressions and the
//! scheduler's scaling behaviour at experiment workloads).
//!
//!   cargo bench --bench experiments

use graft::config::Config;
use graft::experiments;
use graft::profiler::CostModel;
use graft::util::bench::{bench_with, run_group};

fn main() {
    let cm = CostModel::new(Config::embedded());
    // one timed iteration per experiment is plenty — these are seconds-
    // scale end-to-end regenerations
    let quick = |id: &'static str, cm: &CostModel| {
        bench_with(
            id,
            0,
            2,
            std::time::Duration::from_millis(1),
            &mut || experiments::run(id, cm).unwrap().rows.len(),
        )
    };
    run_group(
        "motivation (fig2/fig4/tab2/fig6)",
        vec![
            quick("fig2", &cm),
            quick("fig4", &cm),
            quick("tab2", &cm),
            quick("fig6", &cm),
        ],
    );
    run_group(
        "ablations (fig11..fig16)",
        vec![
            quick("fig11", &cm),
            quick("fig12", &cm),
            quick("fig13", &cm),
            quick("fig14", &cm),
            quick("fig15", &cm),
            quick("fig16", &cm),
        ],
    );
    run_group(
        "latency distributions (fig8..fig10)",
        vec![quick("fig8", &cm), quick("fig9", &cm), quick("fig10", &cm)],
    );
    run_group(
        "scale (fig17/fig18/fig20/fig21)",
        vec![
            quick("fig17", &cm),
            quick("fig18", &cm),
            quick("fig20", &cm),
            quick("fig21", &cm),
        ],
    );
    // fig7/tab3 (10 repetitions x 4 scales x 5 models x 6 systems) and
    // fig19 (contains the exponential Optimal run) are minutes-scale;
    // bench one representative slice instead of the whole table.
    let specs = experiments::common::random_fragments(
        &cm,
        cm.model_index("inc").unwrap(),
        20,
        7,
    );
    run_group(
        "fig7 slice (one snapshot, all systems)",
        vec![bench_with(
            "compare_systems n=20",
            1,
            5,
            std::time::Duration::from_millis(200),
            &mut || {
                use graft::coordinator::baselines::{gslice, gslice_plus};
                use graft::profiler::AllocConstraints;
                let cons = AllocConstraints::default();
                let g = gslice(&cm, &specs, &cons).total_share();
                let gp = gslice_plus(&cm, &specs, &cons).total_share();
                let sched = graft::coordinator::scheduler::Scheduler::new(
                    cm.clone(),
                    Default::default(),
                );
                let (plan, _) = sched.plan(&specs);
                (g, gp, plan.total_share())
            },
        )],
    );
}
