//! Scheduler benches: the L3 hot paths (min_alloc, merging, grouping,
//! Algorithm 1, full plan) at several fragment counts.
//!
//!   cargo bench --bench scheduler

use std::time::Duration;

use graft::config::Config;
use graft::coordinator::grouping::{group_fragments, GroupOptions};
use graft::coordinator::merging::{merge_fragments, MergeOptions};
use graft::coordinator::repartition::{realign_group, RepartitionOptions};
use graft::coordinator::scheduler::{Scheduler, SchedulerOptions};
use graft::experiments::common::{random_fragments, random_mixed_fragments};
use graft::profiler::{AllocConstraints, CostModel, FragmentId};
use graft::util::bench::{bench, bench_with, run_group};

fn main() {
    let cm = CostModel::new(Config::embedded());
    let inc = cm.model_index("inc").unwrap();
    let frag = FragmentId::new(inc, 2, 17);

    run_group(
        "profiler",
        vec![
            bench("min_alloc (feasible)", || {
                cm.min_alloc(frag, 40.0, 120.0, AllocConstraints::default())
            }),
            bench("min_alloc (infeasible)", || {
                cm.min_alloc(frag, 0.4, 5000.0, AllocConstraints::default())
            }),
            bench("latency_ms", || cm.latency_ms(frag, 8, 35)),
        ],
    );

    for &n in &[10usize, 50, 200] {
        let frags = random_fragments(&cm, inc, n, 42);
        let merge_opts = MergeOptions::default();
        let group_opts = GroupOptions::default();
        let mut benches = vec![
            bench(&format!("merge n={n}"), || {
                merge_fragments(&cm, &frags, &merge_opts)
            }),
            bench(&format!("group n={n}"), || {
                group_fragments(&frags, &group_opts)
            }),
        ];
        if n == 10 {
            let small: Vec<_> = frags[..5].to_vec();
            benches.push(bench("realign group-of-5", || {
                realign_group(&cm, &small, &RepartitionOptions::default())
            }));
        }
        let sched = Scheduler::new(cm.clone(), SchedulerOptions::default());
        benches
            .push(bench(&format!("full plan n={n}"), || sched.plan(&frags)));
        run_group(&format!("scheduler n={n}"), benches);
    }

    // Large-scale mixed-model configurations (the 10k-client target of
    // the planner-scaling work; `graft bench-scheduler` times the same
    // demand sets and persists them as BENCH_scheduler.json).
    for &n in &[1_000usize, 5_000, 10_000] {
        let frags = random_mixed_fragments(&cm, n, 0xB15C);
        let cfg = cm.config().clone();
        let cold = big(&format!("full plan n={n} (cold caches)"), || {
            // fresh cost model: empty alloc cache, empty plan cache
            let sched = Scheduler::new(
                CostModel::new(cfg.clone()),
                SchedulerOptions::default(),
            );
            sched.plan(&frags).0.sets.len()
        });
        let warm_sched =
            Scheduler::new(cm.clone(), SchedulerOptions::default());
        let _ = warm_sched.plan(&frags); // fill the caches
        let warm = big(&format!("full plan n={n} (warm/incremental)"), || {
            warm_sched.plan(&frags).0.sets.len()
        });
        run_group(
            &format!("scheduler at scale n={n} (mixed models)"),
            vec![cold, warm],
        );
    }
}

/// Few timed iterations for the seconds-scale large configurations.
fn big<F: FnMut() -> usize>(
    name: &str,
    mut f: F,
) -> graft::util::bench::BenchResult {
    bench_with(name, 1, 3, Duration::from_millis(500), &mut f)
}
